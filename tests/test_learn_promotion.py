"""The promotion state machine and the end-to-end continuous-learning loop.

State-machine tests drive :class:`PromotionController` with synthetic eval
triples injected into the service's shadow dict — the controller's whole
contract is "decide from the checkpointed eval evidence", so the tests pin
each transition against exactly-known evidence:

    idle -> shadowing -> (promote -> watching -> cleared | rollback)
                       | reject -> idle

The closed-loop test at the bottom is the PR's acceptance path: drifting
traffic -> WAL tap -> rolling fine-tune -> shadow-gated promote -> injected
post-promotion regression -> automatic rollback, all through public APIs.
"""
import numpy as np
import pytest

from repro.core import LNNConfig, lnn_init
from repro.core.hetero import ENTITY_TYPE_NAMES
from repro.data import SynthConfig, generate_event_stream
from repro.data.attacks import AttackConfig
from repro.learn import ContinuousLearner, drifting_attack_stream
from repro.learn.promote import PromotionController
from repro.service import (FraudService, ModelSection, ServiceConfig,
                           ServiceLifecycleError)

import jax


@pytest.fixture(scope="module")
def world():
    events, g, _ = generate_event_stream(
        SynthConfig(num_users=30, num_rings=2, feature_noise=0.8, seed=5),
        rate_per_s=500.0)
    cfg = LNNConfig(num_gnn_layers=2, hidden_dim=8,
                    feat_dim=g.order_features.shape[1], mlp_dims=(8,))
    params = lnn_init(jax.random.PRNGKey(0), cfg)
    return events, cfg, params


def _build(cfg, params):
    sc = ServiceConfig(
        mode="streaming", model=ModelSection.from_lnn_config(cfg),
    ).replace(engine={"num_workers": 1, "max_batch": 4})
    return FraudService(sc, params=params).build()


def _controller(svc, **kw):
    kw.setdefault("promote_margin", 0.1)
    kw.setdefault("min_eval", 16)
    kw.setdefault("min_eval_pos", 2)
    kw.setdefault("eval_budget", 0.25)
    kw.setdefault("eval_max", 64)
    kw.setdefault("watch_min_eval", 16)
    kw.setdefault("rollback_margin", 0.1)
    return PromotionController(svc, **kw)


def _inject_eval(svc, triples):
    """Append [label, primary, shadow] rows to the live shadow eval buffer
    — standing in for sampled traffic with exactly-known evidence."""
    with svc._shadow_lock:
        svc._shadow["eval"].extend([list(t) for t in triples])


def _evidence(n=16, pos=4, *, candidate_wins):
    """n triples, ``pos`` positives.  The winner scores positives at 1.0
    (perfect recall@25%); the loser scores them at 0.0 (zero recall)."""
    rows = []
    for i in range(n):
        label = 1.0 if i < pos else 0.0
        good, bad = label, 1.0 - label
        rows.append([label, bad, good] if candidate_wins
                    else [label, good, bad])
    return rows


# --------------------------------------------------------- state transitions
def test_submit_candidate_enables_shadow(world):
    _events, cfg, params = world
    svc = _build(cfg, params)
    ctl = _controller(svc)
    v = ctl.submit_candidate(params)
    assert ctl.state == "shadowing" and ctl.candidate_version == v
    sh = svc.shadow_stats()
    assert sh["role"] == "candidate" and sh["version"] == v
    assert sh["eval"] == [] and sh["eval_max"] == 64
    with pytest.raises(RuntimeError, match="one candidate at a time"):
        ctl.submit_candidate(params)
    assert ctl.stats["submitted"] == 1
    svc.close()


def test_step_waits_for_min_evidence(world):
    _events, cfg, params = world
    svc = _build(cfg, params)
    ctl = _controller(svc)
    ctl.submit_candidate(params)
    assert ctl.step() is None                       # no evidence at all
    _inject_eval(svc, _evidence(n=8, pos=2, candidate_wins=True))
    assert ctl.step() is None                       # n < min_eval
    assert ctl.state == "shadowing"
    svc.close()


def test_promotes_on_margin_then_watches_then_clears(world):
    _events, cfg, params = world
    svc = _build(cfg, params)
    ctl = _controller(svc)
    v = ctl.submit_candidate(params)
    _inject_eval(svc, _evidence(n=20, pos=5, candidate_wins=True))
    d = ctl.step()
    assert d["action"] == "promote"
    assert d["candidate"] == v and d["incumbent"] == 0
    assert d["candidate_recall"] == 1.0 and d["incumbent_recall"] == 0.0
    assert d["n_eval"] == 20
    assert svc.model_version == v                   # hot-swapped live
    # the displaced incumbent now watches the promotee
    assert ctl.state == "watching"
    sh = svc.shadow_stats()
    assert sh["role"] == "last_good" and sh["version"] == 0
    # healthy watch window: promotee keeps its lead until eval_max closes it
    _inject_eval(svc, _evidence(n=64, pos=8, candidate_wins=False))
    # (primary column is the promotee here — and it scores the positives)
    d = ctl.step()
    assert d["action"] == "cleared"
    assert ctl.state == "idle" and svc.shadow_stats() == {}
    assert svc.model_version == v
    assert ctl.stats == {"submitted": 1, "promoted": 1, "rejected": 0,
                         "rollbacks": 0, "cleared": 1}
    svc.close()


def test_rejects_when_margin_not_met(world):
    _events, cfg, params = world
    svc = _build(cfg, params)
    ctl = _controller(svc)
    ctl.submit_candidate(params)
    _inject_eval(svc, _evidence(n=20, pos=5, candidate_wins=False))
    d = ctl.step()
    assert d["action"] == "reject"
    assert svc.model_version == 0                   # incumbent stays
    assert ctl.state == "idle" and svc.shadow_stats() == {}
    assert ctl.stats["rejected"] == 1
    svc.close()


def _enter_watching(svc, ctl_kw=None):
    """Manufacture the post-promotion state: a (perturbed) promotee serving
    as primary, the displaced incumbent shadowing as last-good."""
    bad = svc.register_perturbed(0, scale=2.0)
    svc.activate_model(bad)
    svc.enable_shadow(0, fraction=1.0, threshold=10.0, collect_eval=64,
                      role="last_good")
    ctl = PromotionController.attach(svc, **dict(
        promote_margin=0.1, min_eval=16, min_eval_pos=2, eval_budget=0.25,
        eval_max=64, watch_min_eval=16, rollback_margin=0.1,
        **(ctl_kw or {})))
    assert ctl.state == "watching" and ctl.candidate_version == bad
    return ctl, bad


def test_watch_rolls_back_on_recall_regression(world):
    _events, cfg, params = world
    svc = _build(cfg, params)
    ctl, bad = _enter_watching(svc)
    # the promotee (primary column) misses every positive the last-good
    # shadow still catches — a recall regression past the margin
    _inject_eval(svc, _evidence(n=20, pos=5, candidate_wins=True))
    d = ctl.step()
    assert d["action"] == "rollback" and "recall regression" in d["reason"]
    assert d["restored"] == 0 and svc.model_version == 0
    assert svc.shadow_stats() == {} and ctl.state == "idle"
    assert svc.stats().rollbacks == 1
    assert svc.last_rollback["from"] == bad
    svc.close()


def test_watch_rolls_back_on_divergence_alert(world):
    _events, cfg, params = world
    svc = _build(cfg, params)
    ctl, bad = _enter_watching(svc)
    with svc._shadow_lock:                 # a sampled response tripped it
        svc._shadow["alert_active"] = True
        svc._shadow["divergence_max"] = 0.9
    d = ctl.step()
    assert d["action"] == "rollback" and "divergence" in d["reason"]
    assert svc.model_version == 0 and ctl.state == "idle"
    assert ctl.stats["rollbacks"] == 1
    svc.close()


def test_midstream_hotswap_steals_shadow(world):
    """An operator replacing the shadow mid-eval must not wedge the
    controller: the candidate's evidence is gone, so it resets to idle
    (and a fresh candidate can be submitted)."""
    _events, cfg, params = world
    svc = _build(cfg, params)
    ctl = _controller(svc)
    ctl.submit_candidate(params)
    _inject_eval(svc, _evidence(n=20, pos=5, candidate_wins=True))
    v9 = svc.register_perturbed(0, scale=0.0, version=9)
    svc.enable_shadow(v9, fraction=0.5)    # ops canary steals the slot
    assert ctl.step() is None
    assert ctl.state == "idle" and ctl.candidate_version is None
    assert svc.model_version == 0          # no promotion from stolen state
    ctl.submit_candidate(params)           # machine is reusable
    assert ctl.state == "shadowing"
    svc.close()


def test_midstream_primary_hotswap_during_shadowing(world):
    """A primary hot-swap while a candidate shadows: the paired eval keeps
    meaning (primary column mixes versions, as in production), and a
    promotion still swaps to the candidate."""
    _events, cfg, params = world
    svc = _build(cfg, params)
    ctl = _controller(svc)
    v = ctl.submit_candidate(params)
    _inject_eval(svc, _evidence(n=10, pos=3, candidate_wins=True))
    v2 = svc.register_perturbed(0, scale=0.0, version=v + 7)
    svc.activate_model(v2)                 # operator swaps primary mid-eval
    _inject_eval(svc, _evidence(n=10, pos=3, candidate_wins=True))
    d = ctl.step()
    assert d["action"] == "promote" and d["incumbent"] == v2
    assert svc.model_version == v
    assert svc.last_good_version == v2     # rollback target is the swap-ee
    svc.close()


# ------------------------------------------------- crash/restore mid-eval
def test_crash_mid_shadow_eval_resumes_without_double_count(world, tmp_path):
    events, cfg, params = world
    root = str(tmp_path / "wal")
    svc = _build(cfg, params).enable_wal(root)
    ctl = _controller(svc)
    cand = ctl.submit_candidate(params)
    for ev in events[:12]:
        svc.shadow_observe(svc.submit(ev))
    svc.shadow_observe(svc.drain())
    n1 = len(svc.shadow_stats()["eval"])
    assert n1 > 0
    svc.checkpoint()                       # durable mid-eval
    for ev in events[12:16]:               # post-checkpoint traffic, then
        svc.shadow_observe(svc.submit(ev))  # the process dies
    eval_before = svc.shadow_stats()["eval"]

    svc2 = FraudService.restore(root)
    sh = svc2.shadow_stats()
    # the checkpointed window resumed exactly: the n1 pre-checkpoint triples,
    # once each — replaying the WAL suffix must not re-append them
    assert len(sh["eval"]) == n1
    assert sh["eval"] == eval_before[:n1]
    assert sh["role"] == "candidate" and sh["version"] == cand
    ctl2 = PromotionController.attach(
        svc2, promote_margin=0.1, min_eval=16, min_eval_pos=2,
        eval_budget=0.25, eval_max=64)
    assert ctl2.state == "shadowing" and ctl2.candidate_version == cand
    # fresh traffic keeps filling the SAME window
    for ev in events[16:20]:
        svc2.shadow_observe(svc2.submit(ev))
    svc2.shadow_observe(svc2.drain())
    assert len(svc2.shadow_stats()["eval"]) > n1
    svc.close()
    svc2.close()


def test_crash_mid_watch_restores_last_good_target(world, tmp_path):
    """last_good survives the crash: a restored service can still roll
    back to the displaced incumbent."""
    _events, cfg, params = world
    root = str(tmp_path / "wal")
    svc = _build(cfg, params).enable_wal(root)
    ctl, bad = _enter_watching(svc)
    svc.checkpoint()

    svc2 = FraudService.restore(root)
    assert svc2.model_version == bad
    assert svc2.last_good_version == 0
    ctl2 = PromotionController.attach(svc2)
    assert ctl2.state == "watching"
    with svc2._shadow_lock:
        svc2._shadow["alert_active"] = True
    d = ctl2.step()
    assert d["action"] == "rollback" and svc2.model_version == 0
    svc.close()
    svc2.close()


# --------------------------------------------------------- the closed loop
def test_closed_loop_drift_finetune_promote_rollback(tmp_path):
    """The PR's acceptance path end-to-end through public APIs: drifting
    traffic -> WAL tap -> rolling fine-tune -> shadow-gated promotion ->
    injected post-promotion regression -> automatic rollback."""
    acfg = AttackConfig(num_buyers=60, num_rings=3, ring_size=5,
                        num_snapshots=10, num_bursts=1, num_bin_runs=1,
                        seed=0)
    events, _patterns, split = drifting_attack_stream(acfg, rate_per_s=500.0)
    sc = ServiceConfig.from_dict({
        "mode": "streaming",
        "model": {"num_gnn_layers": 2, "hidden_dim": 8,
                  "feat_dim": int(events[0].features.shape[0]),
                  "mlp_dims": [8], "entity_types": list(ENTITY_TYPE_NAMES)},
        "engine": {"num_workers": 1, "max_batch": 8, "k_max": 4},
        "learn": {"enabled": True, "min_window": 32, "max_window": 128,
                  "stride": 32, "steps": 10, "lr": 1e-2, "min_eval": 16,
                  "min_eval_pos": 2, "eval_max": 64, "promote_margin": 0.0},
    })
    params = lnn_init(jax.random.PRNGKey(0), sc.to_lnn_config())
    svc = FraudService(sc, params=params).build()
    svc.enable_wal(str(tmp_path / "wal"))
    svc.enable_auto_checkpoint(every_windows=3, keep_last=2)
    learner = ContinuousLearner(svc)

    decisions = []
    for i, ev in enumerate(events):
        svc.shadow_observe(svc.submit(ev))
        if (i + 1) % 8 == 0:
            d = learner.step()["decision"]
            if d:
                decisions.append(d)
    svc.drain()

    promotions = [d for d in decisions if d["action"] == "promote"]
    assert promotions, "the loop never promoted a fine-tune"
    # margin-gated: every promotion carried real paired evidence
    assert all(d["n_eval"] >= 16 and d["candidate_recall"]
               >= d["incumbent_recall"] for d in promotions)
    # the tap saw (almost) everything: only events after the last learner
    # tick — at most one stride of 8 — can be un-polled
    assert learner.tap.stats["examples"] >= len(events) - 8 - learner.tap.pending
    assert svc.stats().extra["auto_checkpoint"]["checkpoints"] >= 1
    promoted_v = svc.model_version
    assert promoted_v != 0

    # ---- injected regression: a perturbed promotee must auto-roll-back
    rollbacks_before = svc.stats().rollbacks
    bad = svc.register_perturbed(promoted_v, scale=3.0)
    svc.activate_model(bad)
    svc.enable_shadow(promoted_v, fraction=1.0, threshold=0.05,
                      collect_eval=64, role="last_good")
    watcher = PromotionController.attach(svc, watch_min_eval=8,
                                         rollback_margin=0.05)
    assert watcher.state == "watching"
    rolled = None
    for ev in events[-40:]:
        ev2 = ev.__class__(order_id=ev.order_id + 9_000_000,
                           snapshot=events[-1].snapshot,
                           entities=ev.entities, features=ev.features,
                           label=ev.label, arrival=ev.arrival)
        svc.shadow_observe(svc.submit(ev2))
        rolled = watcher.step()
        if rolled is not None:
            break
    svc.drain()
    assert rolled is not None and rolled["action"] == "rollback"
    assert svc.model_version == promoted_v
    assert svc.stats().rollbacks == rollbacks_before + 1
    learner.close()
    svc.close()
