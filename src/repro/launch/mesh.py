"""Production mesh factory.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before any
device query.

Target hardware: TPU v5e pods, 256 chips each (16x16 ICI torus);
multi-pod = 2 pods / 512 chips over DCN.
"""
from __future__ import annotations

import jax

# v5e hardware constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link (~3 links usable per axis)


def make_production_mesh(*, multi_pod: bool = False, layout: str = "16x16"):
    """layout: '16x16' (mandated production mesh) or an alternative
    (data, model) factorization of the same 256-chip pod — e.g. '32x8' for
    expert-parallel MoE (§Perf B4: the model axis must divide num_experts
    for EP to engage)."""
    if multi_pod:
        shape, axes = (2, 16, 16), ("pod", "data", "model")
    else:
        d, m = (int(x) for x in layout.split("x"))
        assert d * m == 256, f"layout {layout} is not a 256-chip pod"
        shape, axes = (d, m), ("data", "model")
    return jax.make_mesh(shape, axes, **_auto_axis_types(len(axes)))


def _auto_axis_types(n: int) -> dict:
    # jax >= 0.5 wants explicit axis types; 0.4.x has no AxisType at all
    if hasattr(jax.sharding, "AxisType"):
        return {"axis_types": (jax.sharding.AxisType.Auto,) * n}
    return {}


def make_host_mesh():
    """1-device mesh for CPU smoke runs of the same sharded code paths."""
    return jax.make_mesh((1, 1), ("data", "model"), **_auto_axis_types(2))
