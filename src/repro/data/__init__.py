from repro.data.synth import SynthConfig, generate_event_stream, generate_transactions
from repro.data.pipeline import build_communities, make_split_masks

__all__ = ["SynthConfig", "generate_event_stream", "generate_transactions",
           "build_communities", "make_split_masks"]
