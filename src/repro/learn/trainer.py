"""Rolling-window fine-tunes over tap examples (Morpheus-DFP-style).

The trainer holds a bounded buffer of :class:`~repro.learn.tap.TrainingExample`
rows and advances a **rolling window**: once at least ``min_window``
examples are buffered (and ``stride`` new ones since the last fire), it
trains on the newest ``max_window`` examples — deduplicated by order id,
keep-latest, so re-scored orders and label-log corrections supersede
their earlier copies — and records the fire so the next one waits for
another stride of fresh data.

A fine-tune warm-starts from the incumbent's parameters and runs a few
steps of locally-implemented SGD/Adam (no optax) on
:func:`~repro.core.lnn.lnn_loss` over the *window-local* DDS graph: the
window's examples are replayed through a fresh
:class:`~repro.core.dds.IncrementalDDSBuilder`, materialized, and padded
to a power-of-two node budget (bounded jit recompiles, same trick as the
batch-layer refresher).  With ``head="hybrid"`` the tuned stage-1/2
parameters are then frozen and the PR-8 GBDT head is refit on the
window's pre-MLP embeddings (:func:`~repro.models.hybrid.train_hybrid`),
yielding a :class:`~repro.models.hybrid.HybridModel` candidate.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dds import IncrementalDDSBuilder
from repro.core.graph import pad_graph
from repro.core.hetero import type_code_of
from repro.core.lnn import LNNConfig, lnn_loss, lnn_stage1, lnn_stage2_embed

__all__ = ["FineTuneResult", "RollingWindowTrainer", "WindowPolicy",
           "adam", "sgd"]


# ---------------------------------------------------------------- optimizers
def sgd(lr: float = 1e-2, momentum: float = 0.0):
    """Plain (heavy-ball) SGD as an ``(init_fn, update_fn)`` pair —
    ``update_fn(grads, state, params) -> (new_params, new_state)``.
    Local implementation, no optax (mirrors ``repro.train.optim``)."""

    def init_fn(params):
        return jax.tree_util.tree_map(jnp.zeros_like, params)

    def update_fn(grads, state, params):
        vel = jax.tree_util.tree_map(
            lambda v, g: momentum * v + g, state, grads)
        new = jax.tree_util.tree_map(lambda p, v: p - lr * v, params, vel)
        return new, vel

    return init_fn, update_fn


def adam(lr: float = 1e-3, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8):
    """Adam as an ``(init_fn, update_fn)`` pair (bias-corrected moments;
    local implementation, no optax)."""

    def init_fn(params):
        z = jax.tree_util.tree_map(jnp.zeros_like, params)
        return {"step": jnp.zeros((), jnp.int32), "mu": z, "nu": z}

    def update_fn(grads, state, params):
        step = state["step"] + 1
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g, state["mu"], grads)
        nu = jax.tree_util.tree_map(
            lambda n, g: b2 * n + (1 - b2) * g * g, state["nu"], grads)
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)
        new = jax.tree_util.tree_map(
            lambda p, m, n: p - lr * (m / c1) / (jnp.sqrt(n / c2) + eps),
            params, mu, nu)
        return new, {"step": step, "mu": mu, "nu": nu}

    return init_fn, update_fn


_OPTIMIZERS = {"sgd": sgd, "adam": adam}


# -------------------------------------------------------------------- policy
@dataclass(frozen=True)
class WindowPolicy:
    """Rolling-window advance policy: fire on ``min_window`` buffered +
    ``stride`` fresh, train on the newest ``max_window`` (``dedup`` =
    keep-latest per order id)."""

    min_window: int = 32
    max_window: int = 256
    stride: int = 32
    dedup: bool = True

    def __post_init__(self):
        if self.min_window < 1:
            raise ValueError("min_window must be >= 1")
        if self.max_window < self.min_window:
            raise ValueError("max_window must be >= min_window")
        if not (1 <= self.stride <= self.max_window):
            raise ValueError("stride must be in [1, max_window]")


@dataclass
class FineTuneResult:
    """One fine-tune outcome: the candidate model plus its training trace."""

    params: dict                 # tuned LNN pytree
    model: object                # what to register: params, or a HybridModel
    head: str                    # 'mlp' | 'hybrid'
    window: int                  # examples actually trained on (post-dedup)
    steps: int
    losses: list                 # per-step lnn_loss values (python floats)


def _pow2_at_least(n: int, floor: int = 64) -> int:
    p = floor
    while p < n:
        p *= 2
    return p


# ------------------------------------------------------------------- trainer
class RollingWindowTrainer:
    """Accumulate tap examples; fine-tune on rolling windows.

    ``k_max``/``max_deg`` come from the serving engine so the window graph
    is padded the same way the batch layer pads — the candidate sees
    exactly the serving geometry.
    """

    def __init__(self, cfg: LNNConfig, policy: WindowPolicy | None = None, *,
                 optimizer: str = "adam", lr: float = 5e-3, steps: int = 40,
                 head: str = "mlp", gbdt_trees: int = 25, k_max: int = 8,
                 max_deg: int = 32, entity_history: str = "all",
                 max_history: int | None = None):
        if optimizer not in _OPTIMIZERS:
            raise ValueError(f"optimizer must be one of {sorted(_OPTIMIZERS)}")
        if head not in ("mlp", "hybrid"):
            raise ValueError("head must be 'mlp' or 'hybrid'")
        if steps < 1:
            raise ValueError("steps must be >= 1")
        self.cfg = cfg
        self.policy = policy if policy is not None else WindowPolicy()
        self.optimizer, self.lr, self.steps = optimizer, float(lr), int(steps)
        self.head, self.gbdt_trees = head, int(gbdt_trees)
        self.k_max, self.max_deg = int(k_max), int(max_deg)
        self.entity_history, self.max_history = entity_history, max_history
        self._buffer: list = []
        self._since_fire: int | None = None   # None = never fired
        self.stats = {"examples": 0, "fires": 0, "last_window": 0,
                      "last_loss": None}

    # -------------------------------------------------------------- buffering
    def add(self, example) -> None:
        """Buffer one tap example (arrival order)."""
        self._buffer.append(example)
        if self._since_fire is not None:
            self._since_fire += 1
        self.stats["examples"] += 1
        # bound memory: the policy can never look past max_window examples,
        # except that dedup needs slack for superseded duplicates
        cap = 4 * self.policy.max_window
        if len(self._buffer) > cap:
            del self._buffer[: len(self._buffer) - cap]

    def extend(self, examples) -> None:
        """Buffer many tap examples."""
        for ex in examples:
            self.add(ex)

    def ready(self) -> bool:
        """True when the rolling window should advance: enough buffered,
        and a full stride of fresh examples since the last fire."""
        if len(self._buffer) < self.policy.min_window:
            return False
        return self._since_fire is None \
            or self._since_fire >= self.policy.stride

    def _window(self) -> list:
        """The newest ``max_window`` examples, deduped keep-latest."""
        ex = self._buffer
        if self.policy.dedup:
            latest: dict[tuple, object] = {}
            for e in ex:     # later entries overwrite earlier (keep-latest)
                latest[(e.order_id, e.seq if e.order_id < 0 else -1)] = e
            ex = list(latest.values())
        return ex[-self.policy.max_window:]

    # ----------------------------------------------------------------- train
    def train(self, params) -> FineTuneResult:
        """Fine-tune ``params`` on the current window; marks the fire."""
        window = self._window()
        if not window:
            raise ValueError("train() with an empty window")
        self._since_fire = 0
        self.stats["fires"] += 1
        self.stats["last_window"] = len(window)

        dds, pg = self._materialize(window)
        init_fn, update_fn = _OPTIMIZERS[self.optimizer](self.lr)
        loss_grad = jax.jit(jax.value_and_grad(
            lambda p, g: lnn_loss(p, self.cfg, g)))
        opt = init_fn(params)
        losses = []
        for _ in range(self.steps):
            loss, grads = loss_grad(params, pg)
            params, opt = update_fn(grads, opt, params)
            losses.append(float(loss))
        self.stats["last_loss"] = losses[-1]

        model = params
        if self.head == "hybrid":
            model = self._fit_hybrid(params, window, dds, pg)
        return FineTuneResult(params=params, model=model, head=self.head,
                              window=len(window), steps=self.steps,
                              losses=losses)

    def _materialize(self, window):
        """Window examples → window-local DDS graph, padded to pow2 nodes
        (receptive cones are window-local by design: the rolling window IS
        the context the fine-tune sees, matching its serving horizon)."""
        b = IncrementalDDSBuilder(
            feat_dim=self.cfg.feat_dim, entity_history=self.entity_history,
            max_history=self.max_history)
        for e in sorted(window, key=lambda e: (e.snapshot, e.arrival)):
            b.add_order(e.entities, e.snapshot, e.features, e.label)
        dds = b.build()
        pg = pad_graph(dds.coo,
                       num_nodes=_pow2_at_least(dds.coo.num_nodes),
                       max_deg=self.max_deg)
        return dds, pg

    def _fit_hybrid(self, params, window, dds, pg):
        """Refit the GBDT head on the tuned-then-frozen embedding: stage-1
        over the window graph, each order's final-hop cone gathered into
        the online [B, K, H] layout, then ``train_hybrid`` on the pre-MLP
        stage-2 embeddings."""
        from repro.baselines.gbdt import GBDTConfig
        from repro.models.hybrid import train_hybrid

        h = np.asarray(lnn_stage1(params, self.cfg, pg), np.float32)
        n_ord = dds.num_orders
        hid = h.shape[-1]
        ent = np.zeros((n_ord, self.k_max, hid), np.float32)
        mask = np.zeros((n_ord, self.k_max), np.float32)
        slot = np.full((n_ord, self.k_max), -1, np.int32)
        typed = bool(self.cfg.entity_types)
        for o in range(n_ord):
            for k, (e, _t, nid) in enumerate(dds.last_hop.get(o, [])[: self.k_max]):
                ent[o, k] = h[nid]
                mask[o, k] = 1.0
                if typed:
                    slot[o, k] = type_code_of(e)
        feats = np.asarray(pg.features[:n_ord], np.float32)
        emb = np.asarray(lnn_stage2_embed(
            params, self.cfg, ent, mask, feats,
            slot_type=slot if typed else None), np.float32)
        labels = np.asarray(pg.label[:n_ord], np.float32)
        return train_hybrid(params, self.cfg, emb, labels,
                            gbdt_cfg=GBDTConfig(num_trees=self.gbdt_trees))
