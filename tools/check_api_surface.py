"""Public API surface gate (runs in the CI ``lint`` job).

Snapshots the public serving API — ``repro.service.__all__`` plus the shim
modules ``repro.serve`` / ``repro.stream`` — into
``tools/api_surface.json`` and fails when the live surface drifts from the
checked-in snapshot.  A rename, removal, or new export must land together
with a reviewed snapshot update (``--update``), so the serving API can
never change silently under downstream users.

Each ``__all__`` name is also resolved with ``getattr`` — an export that
doesn't import is a failure, not a snapshot diff.

Run:   PYTHONPATH=src python tools/check_api_surface.py
       PYTHONPATH=src python tools/check_api_surface.py --update
"""
from __future__ import annotations

import argparse
import importlib
import json
import os
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, os.path.join(str(ROOT), "src"))

#: the reviewed serving surface: the typed API, the HTTP gateway over it,
#: both shim packages, and the crash-consistency layer
MODULES = ["repro.service", "repro.gateway", "repro.learn", "repro.serve",
           "repro.stream", "repro.stream.checkpoint"]

SNAPSHOT = ROOT / "tools" / "api_surface.json"


def live_surface() -> dict[str, list[str]]:
    surface: dict[str, list[str]] = {}
    for mod_name in MODULES:
        mod = importlib.import_module(mod_name)
        names = getattr(mod, "__all__", None)
        if names is None:
            raise SystemExit(f"FAIL {mod_name}: no __all__ (unreviewable surface)")
        for name in names:
            try:
                getattr(mod, name)
            except AttributeError as exc:
                raise SystemExit(
                    f"FAIL {mod_name}.{name}: listed in __all__ but does not "
                    f"resolve ({exc})"
                ) from exc
        surface[mod_name] = sorted(names)
    return surface


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--update", action="store_true",
                    help="rewrite the snapshot to the live surface "
                         "(do this in the same PR as the API change)")
    args = ap.parse_args(argv)

    surface = live_surface()
    if args.update:
        SNAPSHOT.write_text(json.dumps(surface, indent=1) + "\n")
        print(f"api surface snapshot updated ({SNAPSHOT.relative_to(ROOT)})")
        return 0

    if not SNAPSHOT.exists():
        print(f"FAIL: snapshot missing — run: python {Path(__file__).name} --update")
        return 1
    recorded = json.loads(SNAPSHOT.read_text())
    failed = False
    for mod_name in sorted(set(recorded) | set(surface)):
        old = set(recorded.get(mod_name, []))
        new = set(surface.get(mod_name, []))
        for name in sorted(new - old):
            print(f"FAIL {mod_name}: unreviewed new export '{name}'")
            failed = True
        for name in sorted(old - new):
            print(f"FAIL {mod_name}: export '{name}' removed from the surface")
            failed = True
    if failed:
        print("api surface drift — review the change, then run "
              "`python tools/check_api_surface.py --update` in the same PR")
        return 1
    total = sum(len(v) for v in surface.values())
    print(f"api surface OK ({len(surface)} modules, {total} exports)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
