"""granite-3-2b — dense GQA decoder [hf:ibm-granite/granite-3.0-2b-base].

40L, d_model=2048, 32 q heads (head_dim 64), 8 kv heads, d_ff=8192 (swiglu),
vocab=49155.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-2b",
    arch_type="dense",
    num_layers=40,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=49155,
    source="[hf:ibm-granite/granite-3.0-2b-base]",
)
