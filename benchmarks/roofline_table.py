"""Aggregate experiments/dryrun/*.json into the EXPERIMENTS.md roofline table."""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_records(mesh: str = "single", tag: str | None = None):
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        base = os.path.basename(path)[:-5]
        parts = base.split("__")
        if len(parts) < 3:
            continue
        mesh_part = parts[2]
        has_tag = "_" in mesh_part
        if tag is None and has_tag:
            continue
        if tag is not None and mesh_part != f"{mesh}_{tag}":
            continue
        if tag is None and mesh_part != mesh:
            continue
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_ms(s):
    return f"{s*1e3:.1f}"


def make_table(mesh: str = "single", tag: str | None = None) -> str:
    recs = load_records(mesh, tag)
    by_key = {(r.get("arch"), r.get("shape")): r for r in recs}
    archs = sorted({r.get("arch") for r in recs if r.get("arch")})
    lines = [
        "| arch | shape | Tc (ms) | Tm (ms) | Tcoll (ms) | bottleneck | "
        "HLO GFLOP/chip | useful | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in archs:
        for shape in SHAPE_ORDER:
            r = by_key.get((arch, shape))
            if r is None:
                continue
            if r.get("status") == "skip":
                lines.append(f"| {arch} | {shape} | — | — | — | SKIP | — | — | "
                             f"{r.get('reason','')} |")
                continue
            lines.append(
                f"| {arch} | {shape} | {fmt_ms(r['t_compute'])} | "
                f"{fmt_ms(r['t_memory'])} | {fmt_ms(r['t_collective'])} | "
                f"**{r['bottleneck']}** | {r['hlo_gflops']/r['chips']:.0f} | "
                f"{r['useful_ratio']:.2f} | {r.get('note','')} |"
            )
    return "\n".join(lines)


if __name__ == "__main__":
    import sys

    mesh = sys.argv[1] if len(sys.argv) > 1 else "single"
    print(make_table(mesh))
