"""`repro.service`: config round-trip, lifecycle, facade equivalence with the
legacy entry points (bit-identical), versioned hot-swap, admission control."""
import math
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import LNNConfig, lnn_init
from repro.data import SynthConfig, generate_event_stream
from repro.service import (
    FraudService,
    ModelSection,
    ScoreRequest,
    ServiceConfig,
    ServiceLifecycleError,
)


@pytest.fixture(scope="module")
def service_world():
    events, g, _ = generate_event_stream(
        SynthConfig(num_users=70, num_rings=3, feature_noise=0.8, seed=7),
        rate_per_s=500.0,
    )
    cfg = LNNConfig(num_gnn_layers=3, hidden_dim=32,
                    feat_dim=g.order_features.shape[1])
    params = lnn_init(jax.random.PRNGKey(0), cfg)
    sc = ServiceConfig(model=ModelSection.from_lnn_config(cfg)).replace(
        engine={"max_batch": 8})
    return events, cfg, params, sc


def _legacy_engine(params, cfg, **engine_kw):
    from repro.stream import EngineConfig, StreamingEngine

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return StreamingEngine(params, cfg, EngineConfig(**engine_kw))


# ------------------------------------------------------------ ServiceConfig
def test_service_config_json_roundtrip(tmp_path):
    sc = ServiceConfig(
        mode="streaming",
        model=ModelSection(gnn_type="gat", hidden_dim=32, mlp_dims=(16, 8),
                           feat_dim=12),
    ).replace(
        engine={"num_workers": 4, "steal_threshold": 10, "max_history": None},
        store={"capacity": 1000, "ttl_seconds": 5.0},
        refresh={"refresh_every": 3, "async_refresh": True},
        admission={"max_queue_depth": 32, "policy": "block"},
    )
    assert ServiceConfig.from_json(sc.to_json()) == sc
    path = str(tmp_path / "svc.json")
    sc.save(path)
    loaded = ServiceConfig.load(path)
    assert loaded == sc
    # tuples survive the JSON list round-trip
    assert loaded.model.mlp_dims == (16, 8)
    assert isinstance(loaded.model.mlp_dims, tuple)
    # the artifact rebuilds the legacy configs exactly
    assert loaded.to_lnn_config().gnn_type == "gat"
    ecfg = loaded.to_engine_config()
    assert (ecfg.num_workers, ecfg.refresh_every, ecfg.store_capacity) == (4, 3, 1000)


def test_service_config_rejects_unknown_keys():
    with pytest.raises(ValueError, match="unknown key"):
        ServiceConfig.from_dict({"modle": "batch"})
    with pytest.raises(ValueError, match=r"ServiceConfig\.engine"):
        ServiceConfig.from_dict({"engine": {"max_batchh": 4}})
    with pytest.raises(ValueError, match=r"ServiceConfig\.admission"):
        ServiceConfig.from_dict({"admission": {"policy": "shed", "shed": 1}})
    # replace() applies the same rejection to section-dict overrides
    with pytest.raises(ValueError, match="unknown key"):
        ServiceConfig().replace(engine={"nope": 1})


def test_service_config_validation():
    with pytest.raises(ValueError, match="mode"):
        ServiceConfig(mode="realtime")
    with pytest.raises(ValueError, match="policy"):
        ServiceConfig.from_dict({"admission": {"policy": "drop"}})
    with pytest.raises(ValueError, match="num_workers"):
        ServiceConfig().replace(engine={"num_workers": 0})


# ---------------------------------------------------------------- lifecycle
def test_lifecycle_is_enforced(service_world):
    events, cfg, params, sc = service_world
    svc = FraudService(sc, params=params)
    assert svc.state == "created"
    with pytest.raises(ServiceLifecycleError, match="submit"):
        svc.submit(events[0])
    with pytest.raises(ServiceLifecycleError, match="warmup"):
        svc.warmup()
    svc.build()
    assert svc.state == "built"
    with pytest.raises(ServiceLifecycleError, match="build"):
        svc.build()
    svc.warmup()
    assert svc.state == "ready"
    out = svc.submit(events[0])
    assert svc.state == "serving"
    out += svc.drain()
    assert svc.state == "drained" and len(out) == 1
    svc.close()
    assert svc.state == "closed"
    svc.close()          # idempotent
    for op in (svc.drain, svc.warmup, lambda: svc.submit(events[0])):
        with pytest.raises(ServiceLifecycleError):
            op()
    with pytest.raises(ServiceLifecycleError, match="load_model"):
        svc.load_model(params)


def test_build_requires_a_model(service_world):
    _, _, params, sc = service_world
    svc = FraudService(sc)
    with pytest.raises(ServiceLifecycleError, match="load_model"):
        svc.build()
    svc.load_model(params)
    svc.build()
    assert svc.state == "built"


def test_mode_guards(service_world, small_communities):
    events, cfg, params, sc = service_world
    streaming = FraudService(sc, params=params).build()
    with pytest.raises(ServiceLifecycleError, match="mode='batch'"):
        streaming.refresh(small_communities)
    batch = FraudService(sc.replace(mode="batch"), params=params).build()
    with pytest.raises(ServiceLifecycleError, match="mode='streaming'"):
        batch.submit(events[0])


# ----------------------------------------------- facade equivalence (batch)
def test_batch_mode_bit_identical_to_lambda_pipeline(small_communities):
    """Acceptance: FraudService(mode='batch') scores == LambdaPipeline.score
    bitwise, over the same refreshed store contents."""
    from repro.serve import LambdaPipeline, history_requests

    feat_dim = small_communities[0].graph.features.shape[1]
    cfg = LNNConfig(num_gnn_layers=3, hidden_dim=32, feat_dim=feat_dim)
    params = lnn_init(jax.random.PRNGKey(2), cfg)

    with pytest.warns(DeprecationWarning, match="FraudService"):
        pipe = LambdaPipeline(params, cfg, k_max=8)
    pipe.refresh(small_communities)
    requests = history_requests(small_communities)
    assert requests
    ref = pipe.score(requests)

    sc = ServiceConfig(mode="batch", model=ModelSection.from_lnn_config(cfg))
    svc = FraudService(sc, params=params).build().warmup()
    svc.refresh(small_communities)
    out = svc.score(requests)
    got = np.asarray([r.score for r in out])
    np.testing.assert_array_equal(got, ref)
    assert all(r.admitted and r.model_version == 0 for r in out)
    # the facade proves the same split-equivalence bound — WITHOUT the
    # internal verification replay counting as served traffic
    before = svc.stats().requests
    assert svc.score_equivalence_check(small_communities) < 1e-4
    assert svc.stats().requests == before
    # legacy dict requests still accepted (shim compatibility)
    legacy = [{"features": r.features, "entity_keys": r.entity_keys}
              for r in requests[:4]]
    np.testing.assert_array_equal(
        np.asarray([r.score for r in svc.score(legacy)]), ref[:4])


def test_equivalence_check_unaffected_by_shed_admission(small_communities):
    """The internal verification replay must bypass admission: a shed policy
    that would NaN-out tail requests cannot fail the check spuriously."""
    feat_dim = small_communities[0].graph.features.shape[1]
    cfg = LNNConfig(num_gnn_layers=2, hidden_dim=16, feat_dim=feat_dim)
    params = lnn_init(jax.random.PRNGKey(0), cfg)
    svc = FraudService(
        ServiceConfig(mode="batch", model=ModelSection.from_lnn_config(cfg))
        .replace(admission={"max_queue_depth": 2, "policy": "shed"}),
        params=params).build()
    svc.refresh(small_communities)
    assert svc.score_equivalence_check(small_communities) < 1e-4


# ------------------------------------------- facade equivalence (streaming)
@pytest.mark.parametrize("num_workers", [1, 4])
def test_streaming_mode_bit_identical_to_engine(service_world, num_workers):
    """Acceptance: FraudService(mode='streaming').replay == legacy
    StreamingEngine.replay bitwise, for N=1 and N=4 workers."""
    events, cfg, params, sc = service_world
    ref = _legacy_engine(params, cfg, max_batch=8).replay(events)
    s_ref = ref.scores_by_order()

    svc = FraudService(
        sc.replace(engine={"max_batch": 8, "num_workers": num_workers}),
        params=params).build()
    rep = svc.replay(events)
    s = rep.scores_by_order()
    assert set(s) == set(s_ref)
    assert all(s[o] == s_ref[o] for o in s_ref)
    st = svc.stats()
    assert st.requests == len(events) and st.scored == len(events)
    assert st.shed == 0 and st.blocked == 0


def test_replay_report_summary_single_latency_pass(service_world):
    events, cfg, params, sc = service_world
    svc = FraudService(sc, params=params).build()
    rep = svc.replay(events[:60])
    s = rep.summary()
    # percentiles and mean come from the same cached pass
    assert s["mean_latency_ms"] == rep.percentiles_ms()["mean"]
    assert set(rep.percentiles_ms()) == {"p50", "p95", "p99", "mean"}


# ----------------------------------------------------------------- hot-swap
def test_hot_swap_mid_stream_replay_parity(service_world):
    """Registering an identical-weights copy as a new version mid-stream
    must leave every score bit-identical, while the machinery visibly
    swaps: results flushed after the swap carry the new version, KV puts
    are re-stamped, and pre-swap embeddings read back as model-stale."""
    events, cfg, params, sc = service_world
    s_ref = _legacy_engine(params, cfg, max_batch=8).replay(events).scores_by_order()

    params_copy = jax.tree_util.tree_map(jnp.asarray, params)
    svc = FraudService(sc, params=params).build().warmup()
    out = []
    half = len(events) // 2
    for ev in events[:half]:
        out.extend(svc.submit(ev))
    assert svc.load_model(params_copy) == 1
    for ev in events[half:]:
        out.extend(svc.submit(ev))
    out.extend(svc.drain())

    scores = {r.request.tag.order_id: r.score for r in out}
    assert set(scores) == set(s_ref)
    assert all(scores[o] == s_ref[o] for o in s_ref)
    # both versions actually served flushes, in order: v0 then v1
    versions = [r.model_version for r in out]
    assert set(versions) == {0, 1}
    assert versions == sorted(versions)
    st = svc.stats()
    assert st.model_versions == (0, 1) and st.model_version == 1
    assert st.model_swaps == 1
    # post-swap reads of pre-swap embeddings were detected, not silent
    assert st.model_stale_reads > 0


def test_hot_swap_new_flushes_score_on_new_params(service_world):
    """With genuinely different params, flushes after the swap must score
    under the new model: their responses differ from the old model's and
    are stamped with the new version."""
    events, cfg, params, sc = service_world
    params2 = lnn_init(jax.random.PRNGKey(99), cfg)
    evs = events[:80]
    s_old = _legacy_engine(params, cfg, max_batch=8).replay(evs).scores_by_order()

    svc = FraudService(sc, params=params).build().warmup()
    out = []
    for ev in evs[:40]:
        out.extend(svc.submit(ev))
    svc.load_model(params2, version=7)
    for ev in evs[40:]:
        out.extend(svc.submit(ev))
    out.extend(svc.drain())
    new = [r for r in out if r.model_version == 7]
    assert new, "no flush scored under the swapped model"
    diffs = [abs(r.score - s_old[r.request.tag.order_id]) for r in new]
    assert max(diffs) > 0, "post-swap flushes still scored with old params"
    # swapping BACK reuses the registered version (and its jit cache)
    assert svc.load_model(params, version=0) == 0
    assert svc.model_versions() == (0, 7)


def test_refresh_driver_stamps_model_version(service_world):
    events, cfg, params, sc = service_world
    svc = FraudService(sc, params=params).build()
    for ev in events[:30]:
        svc.submit(ev)
    svc.load_model(jax.tree_util.tree_map(jnp.asarray, params), version=3)
    for ev in events[30:]:
        svc.submit(ev)
    svc.drain()
    versions = {svc.store.version_of(k) is not None
                for k in svc.store.keys()}
    assert versions == {True}
    entries = [svc.store.get_entry(k) for k in svc.store.keys()]
    assert entries  # store populated
    model_versions = {e.model_version
                      for shard in svc.store._shards for e in shard.values()}
    assert model_versions == {0, 3}, model_versions


# --------------------------------------------------------------- admission
def test_streaming_admission_shed_accounting(service_world):
    events, cfg, params, sc = service_world
    svc = FraudService(
        sc.replace(engine={"max_batch": 8, "num_workers": 2,
                           "service_model_s": 0.05},
                   admission={"max_queue_depth": 6, "policy": "shed"}),
        params=params).build()
    rep = svc.replay(events)
    st = svc.stats()
    assert st.shed > 0 and st.blocked == 0
    assert st.requests == len(events)
    assert st.shed + len(rep.results) == len(events)
    # shed never inflates the enforced cap
    assert st.queue_depth_peak <= 6
    # report only carries admitted scores; shed ones were NaN + flagged
    assert all(r.admitted for r in rep.results)


def test_streaming_admission_block_accounting(service_world):
    events, cfg, params, sc = service_world
    svc = FraudService(
        sc.replace(engine={"max_batch": 8, "num_workers": 2,
                           "service_model_s": 0.05},
                   admission={"max_queue_depth": 6, "policy": "block"}),
        params=params).build()
    rep = svc.replay(events)
    st = svc.stats()
    assert st.blocked > 0 and st.shed == 0
    # backpressure loses nothing
    assert len(rep.results) == len(events)
    assert {r.request.tag.order_id for r in rep.results} \
        == {ev.order_id for ev in events}
    # the cap is actually enforced: the block drain must keep freeing
    # capacity even when the reorder buffer withholds flushed results
    # (regression: the loop used to give up on an empty release)
    assert st.queue_depth_peak <= 6


def test_streaming_shed_response_shape(service_world):
    events, cfg, params, sc = service_world
    svc = FraudService(
        sc.replace(engine={"max_batch": 64, "max_wait_s": 1e9},
                   admission={"max_queue_depth": 1, "policy": "shed"}),
        params=params).build()
    out = []
    for ev in events[:3]:
        out.extend(svc.submit(ev))
    shed = [r for r in out if not r.admitted]
    assert len(shed) == 2           # first fills the queue, rest shed
    assert all(math.isnan(r.score) for r in shed)
    assert all(isinstance(r.request, ScoreRequest) for r in shed)


def test_batch_admission_shed_and_block(small_communities):
    feat_dim = small_communities[0].graph.features.shape[1]
    cfg = LNNConfig(num_gnn_layers=2, hidden_dim=16, feat_dim=feat_dim)
    params = lnn_init(jax.random.PRNGKey(0), cfg)
    from repro.serve import history_requests

    base = ServiceConfig(mode="batch", model=ModelSection.from_lnn_config(cfg))
    ref_svc = FraudService(base, params=params).build()
    ref_svc.refresh(small_communities)
    requests = history_requests(small_communities)[:30]
    ref = np.asarray([r.score for r in ref_svc.score(requests)])

    shed_svc = FraudService(
        base.replace(admission={"max_queue_depth": 10, "policy": "shed"}),
        params=params, store=ref_svc.store).build()
    out = shed_svc.score(requests)
    kept = [r for r in out if r.admitted]
    assert len(kept) == 10 and shed_svc.stats().shed == 20
    np.testing.assert_array_equal(np.asarray([r.score for r in kept]), ref[:10])

    block_svc = FraudService(
        base.replace(admission={"max_queue_depth": 16, "policy": "block"}),
        params=params, store=ref_svc.store).build()
    out = block_svc.score(requests)
    assert all(r.admitted for r in out)
    assert block_svc.stats().blocked == 14
    np.testing.assert_array_equal(np.asarray([r.score for r in out]), ref)


# -------------------------------------------------------- shims + artifacts
def test_deprecation_shims_importable_and_warn(service_world):
    events, cfg, params, sc = service_world
    from repro.serve import LambdaPipeline
    from repro.stream import EngineConfig, StreamingEngine

    with pytest.warns(DeprecationWarning, match="FraudService"):
        LambdaPipeline(params, cfg)
    with pytest.warns(DeprecationWarning, match="FraudService"):
        StreamingEngine(params, cfg, EngineConfig())


def test_stream_request_types_are_the_service_types():
    """One request/response vocabulary: the streaming engine's classes ARE
    the service-level ones (not parallel near-duplicates)."""
    from repro.service.types import ScoreRequest as SR, ScoreResponse as SP
    from repro.stream import ScoredResult
    from repro.stream import ScoreRequest as StreamSR

    assert StreamSR is SR
    assert ScoredResult is SP


def test_from_artifact_and_context_manager(service_world, tmp_path):
    events, cfg, params, sc = service_world
    path = str(tmp_path / "service.json")
    sc.save(path)
    with FraudService.from_artifact(path, params=params) as svc:
        svc.submit(events[0])
        svc.drain()
        assert svc.stats().scored == 1
    assert svc.state == "closed"


def test_stats_to_dict_is_json_safe(service_world):
    import json

    events, cfg, params, sc = service_world
    svc = FraudService(sc, params=params).build()
    svc.replay(events[:40])
    d = svc.stats().to_dict()
    json.dumps(d)        # must not raise
    assert d["mode"] == "streaming" and d["requests"] == 40


def test_service_stats_json_roundtrip():
    """Every counter survives to_dict -> JSON -> from_dict bit-for-bit: the
    gateway's /v1/stats and /metrics render from this ONE snapshot, so a
    field that doesn't round-trip is a field that silently falls off the
    wire.  The sample below must set EVERY dataclass field to a non-default
    value — adding a field without extending it fails here."""
    import dataclasses
    import json

    from repro.service import ServiceStats

    sample = ServiceStats(
        mode="streaming", state="serving", model_version=3,
        model_versions=(0, 3, 9), model_swaps=2, requests=100, scored=90,
        shed=7, blocked=5, block_timeouts=3, queue_depth=4,
        queue_depth_peak=12, in_flight_peak=2, flushes=31, refreshes=6,
        entities_written=250, model_stale_reads=11, store_size=420,
        rollbacks=1, last_good_version=0,
        scores_by_version={0: 40, 3: 50},
        shadow={"version": 9, "fraction": 0.5, "threshold": 0.25,
                "sampled": 45, "divergence_sum": 0.5, "divergence_max": 0.1,
                "last_divergence": 0.01, "alerts": 1, "alert_active": True},
        store_stats={"hits": 10, "model_stale_reads": 11},
        workers=[{"worker": 0, "queue_depth": 2, "flushes": 5,
                  "stolen_in": 1, "stolen_out": 0, "restarts": 0,
                  "alive": True}],
        extra={"pool": {"steals": 1}},
    )
    defaults = ServiceStats()
    for f in dataclasses.fields(ServiceStats):
        assert getattr(sample, f.name) != getattr(defaults, f.name), \
            f"test sample leaves ServiceStats.{f.name} at its default — " \
            "extend the sample so the round-trip exercises it"

    wire = json.loads(json.dumps(sample.to_dict()))
    back = ServiceStats.from_dict(wire)
    assert back == sample
    assert isinstance(back.model_versions, tuple)
    # JSON stringifies mapping keys; from_dict restores the int versions
    assert back.scores_by_version == {0: 40, 3: 50}
    with pytest.raises(ValueError, match="unknown key"):
        ServiceStats.from_dict({**wire, "scoredd": 1})

    # the live service produces the same lossless round-trip
    live = ServiceStats.from_dict(json.loads(json.dumps(sample.to_dict())))
    assert live.to_dict() == sample.to_dict()


# ------------------------------------------------- bounded block-mode stalls
def test_block_admission_bounded_wait(service_world):
    """Regression: block-mode admission used to wait unboundedly (and then
    admit over-cap) when force-flushing the deepest queue freed nothing.
    ``admission.block_max_wait_s`` bounds the stall on the wall clock and
    sheds on timeout — counted in ``ServiceStats.block_timeouts``."""
    events, cfg, params, sc = service_world

    # zero budget: the stall times out immediately -> timed-out shed
    svc = FraudService(
        sc.replace(engine={"max_batch": 64, "max_wait_s": 1e9},
                   admission={"max_queue_depth": 1, "policy": "block",
                              "block_max_wait_s": 0.0}),
        params=params).build()
    out = [r for ev in events[:3] for r in svc.submit(ev)]
    shed = [r for r in out if not r.admitted]
    assert len(shed) == 2 and all(math.isnan(r.score) for r in shed)
    st = svc.stats()
    assert st.block_timeouts == 2 and st.shed == 2 and st.blocked == 2
    # the bounded block never admits over-cap
    assert st.queue_depth_peak <= 1

    # a generous budget behaves like classic block: force-flushes free
    # capacity, everything is admitted, nothing times out
    svc2 = FraudService(
        sc.replace(engine={"max_batch": 8, "num_workers": 2,
                           "service_model_s": 0.05},
                   admission={"max_queue_depth": 6, "policy": "block",
                              "block_max_wait_s": 30.0}),
        params=params).build()
    rep = svc2.replay(events)
    st2 = svc2.stats()
    assert len(rep.results) == len(events)
    assert st2.blocked > 0 and st2.block_timeouts == 0 and st2.shed == 0


def test_drain_to_depth_clock_semantics(service_world):
    """WorkerPool.drain_to_depth: a finite budget times the stall out on the
    injected clock even when a flush WOULD free capacity; budget=None keeps
    the legacy unbounded semantics (flush until below cap)."""
    events, cfg, params, sc = service_world
    sc = sc.replace(engine={"max_batch": 64, "max_wait_s": 1e9})

    def fill(svc, n=4):
        for ev in events[:n]:
            svc.submit(ev)
        return svc.engine.pool

    # budget expires before the first flush pass -> not admitted, queue kept
    svc = FraudService(sc, params=params).build()
    pool = fill(svc)
    depth0 = len(pool)
    assert depth0 == 4
    ticks = iter([0.0, 100.0])
    drained, admitted = pool.drain_to_depth(
        1, events[3].arrival, budget_s=5.0, clock=lambda: next(ticks))
    assert not admitted and drained == [] and len(pool) == depth0

    # same pool, no budget: the legacy path flushes down below the cap
    drained, admitted = pool.drain_to_depth(1, events[3].arrival, budget_s=None)
    assert admitted and len(drained) == depth0 and len(pool) == 0


def test_block_max_wait_validation():
    with pytest.raises(ValueError, match="block_max_wait_s"):
        ServiceConfig.from_dict(
            {"admission": {"policy": "block", "block_max_wait_s": -1.0}})
    # round-trips with the rest of the admission section
    sc = ServiceConfig().replace(
        admission={"policy": "block", "block_max_wait_s": 0.25})
    assert ServiceConfig.from_json(sc.to_json()).admission.block_max_wait_s == 0.25
