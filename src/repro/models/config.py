"""Architecture configuration for the assigned model zoo.

One ``ArchConfig`` instance per assigned architecture lives in
``repro/configs/<id>.py``; reduced smoke variants derive from the same
dataclass via ``reduced()``.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

from repro.utils.padding import pad_to_multiple


@dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str                  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                  # query heads (0 for attn-free SSM)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    source: str = ""                # citation ([arXiv:...] / [hf:...])

    # attention details
    window: int | None = None       # sliding-window attention
    ring_kv_cache: bool = False     # SWA decode: cache only the last `window`
                                    # positions (ring buffer) — beyond-paper
    qkv_bias: bool = False          # qwen1.5
    nonparametric_ln: bool = False  # olmo
    rope_theta: float = 10_000.0

    # MoE
    num_experts: int = 0
    experts_per_token: int = 2
    moe_capacity_factor: float = 1.25

    # SSM (mamba2 / zamba2)
    ssm_state: int = 0              # N
    ssm_head_dim: int = 64          # P
    ssm_expand: int = 2
    conv_kernel: int = 4
    ssd_chunk: int = 64             # SSD chunk length (XLA path)
    ssd_compute_dtype: str = "float32"  # intra-chunk tensor dtype (§Perf: bfloat16)

    # hybrid (zamba2): one *shared* attention block applied after every
    # ``attn_every`` mamba blocks
    attn_every: int = 0

    # VLM (llama-3.2-vision): a cross-attention layer every ``cross_attn_every``
    # layers; vision frontend is a stub providing ``num_vision_tokens``
    # pre-projected patch embeddings
    cross_attn_every: int = 0
    num_vision_tokens: int = 0

    # audio (seamless): encoder-decoder; ``num_layers`` applies to each side;
    # frontend stub provides pre-computed audio frame embeddings
    encdec: bool = False
    ffn_type: str = "swiglu"        # swiglu | gelu

    # numerics / distribution
    dtype: str = "bfloat16"
    # physical padding for the fixed model axis (set by the launcher;
    # 0 = no padding).  Logical config stays exact.
    pad_heads_to: int = 0
    pad_kv_heads_to: int = 0
    pad_vocab_to_multiple: int = 256

    # ------------------------------------------------------------------ api
    @property
    def d_inner(self) -> int:       # mamba inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def physical_heads(self) -> int:
        if self.pad_heads_to:
            return pad_to_multiple(self.num_heads, self.pad_heads_to)
        return self.num_heads

    @property
    def physical_kv_heads(self) -> int:
        if self.pad_kv_heads_to:
            # GQA kv replication: pad kv heads up to the model-axis size by
            # physically repeating groups (vLLM/MaxText practice)
            if self.num_kv_heads < self.pad_kv_heads_to:
                return self.pad_kv_heads_to
            return pad_to_multiple(self.num_kv_heads, self.pad_kv_heads_to)
        return self.num_kv_heads

    @property
    def physical_vocab(self) -> int:
        return pad_to_multiple(self.vocab_size, self.pad_vocab_to_multiple)

    def with_padding(self, model_axis: int) -> "ArchConfig":
        """Return a copy physically padded for an N-way tensor-parallel axis."""
        return replace(
            self,
            pad_heads_to=model_axis if self.num_heads else 0,
            pad_kv_heads_to=model_axis if self.num_kv_heads else 0,
            pad_vocab_to_multiple=max(self.pad_vocab_to_multiple, model_axis),
        )

    def unit_dims(self) -> list[tuple[str, int]]:
        """Layer-group unit dimensions for dry-run cost extrapolation.

        Returns [(unit_name, real_count)] such that total cost is affine in
        each count; ``with_unit_counts`` builds the small variants."""
        if self.arch_type == "hybrid":
            n_super, tail = divmod(self.num_layers, self.attn_every)
            dims = [("super", n_super)]
            if tail:
                dims.append(("tail", tail))
            return dims
        if self.arch_type == "vlm":
            return [("super", self.num_layers // self.cross_attn_every)]
        return [("layers", self.num_layers)]

    def with_unit_counts(self, counts: dict) -> "ArchConfig":
        if self.arch_type == "hybrid":
            n_super, tail = divmod(self.num_layers, self.attn_every)
            c_super = counts.get("super", n_super)
            c_tail = counts.get("tail", tail)
            return replace(self, num_layers=self.attn_every * c_super + c_tail)
        if self.arch_type == "vlm":
            c = counts.get("super", self.num_layers // self.cross_attn_every)
            return replace(self, num_layers=self.cross_attn_every * c)
        return replace(self, num_layers=counts.get("layers", self.num_layers))

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: <=2 layers (or superblocks), small dims."""
        return replace(
            self,
            num_layers=min(self.num_layers, 2 * max(self.attn_every, 1)
                           if self.attn_every else
                           (2 * max(self.cross_attn_every, 1) if self.cross_attn_every else 2)),
            d_model=min(self.d_model, 256),
            num_heads=min(self.num_heads, 4) if self.num_heads else 0,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 1024),
            head_dim=64,
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            moe_capacity_factor=8.0,   # no drops at smoke-test scale

            ssm_state=min(self.ssm_state, 32) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else self.ssm_head_dim,
            window=min(self.window, 64) if self.window else None,
            num_vision_tokens=min(self.num_vision_tokens, 16)
            if self.num_vision_tokens
            else 0,
            dtype="float32",
            pad_heads_to=0,
            pad_kv_heads_to=0,
            pad_vocab_to_multiple=8,
        )


# the four assigned input shapes ---------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
