"""Shared building blocks: norms, RoPE, FFNs, blockwise attention, losses.

Everything is a pure function over explicit param pytrees (no flax), so the
same code paths serve training, prefill, decode and the 512-device dry-run
lowering without retracing surprises.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(rng, shape, dtype, scale=None):
    fan_in = shape[0]
    if scale is None:
        scale = fan_in ** -0.5
    return (jax.random.normal(rng, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x, scale=None, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * (1.0 + scale.astype(jnp.float32))
    return y.astype(x.dtype)


def layernorm_nonparametric(x, eps=1e-5):
    """OLMo's non-parametric LayerNorm: no learnable scale/bias [arXiv:2402.00838]."""
    x32 = x.astype(jnp.float32)
    mu = x32.mean(-1, keepdims=True)
    var = x32.var(-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: [..., S, Dh]; positions: broadcastable to [..., S]."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # [Dh/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, Dh/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------

def ffn_init(rng, d_model, d_ff, ffn_type, dtype):
    ks = jax.random.split(rng, 3)
    if ffn_type == "swiglu":
        return {
            "w_gate": dense_init(ks[0], (d_model, d_ff), dtype),
            "w_up": dense_init(ks[1], (d_model, d_ff), dtype),
            "w_down": dense_init(ks[2], (d_ff, d_model), dtype),
        }
    return {
        "w_up": dense_init(ks[0], (d_model, d_ff), dtype),
        "w_down": dense_init(ks[1], (d_ff, d_model), dtype),
    }


def ffn_apply(params, x, ffn_type):
    if ffn_type == "swiglu":
        g = jax.nn.silu((x @ params["w_gate"]).astype(jnp.float32)).astype(x.dtype)
        return ((g * (x @ params["w_up"])) @ params["w_down"])
    h = jax.nn.gelu((x @ params["w_up"]).astype(jnp.float32)).astype(x.dtype)
    return h @ params["w_down"]


# ---------------------------------------------------------------------------
# Blockwise (flash-style) attention in pure lax — the XLA path used for
# training/prefill.  O(S) memory via online softmax over KV blocks.
# ---------------------------------------------------------------------------

def blockwise_attention(q, k, v, *, causal=True, window=None, block_k=512,
                        q_offset=None):
    """q: [B, Hq, Sq, Dh]; k/v: [B, Hkv, Sk, Dh].  GQA via head grouping
    (no K/V repetition is materialized).  Returns [B, Hq, Sq, Dh].

    ``q_offset``: absolute position of q row 0 (default aligns q to the end
    of the kv sequence, the prefill/train convention).
    """
    b, hq, sq, dh = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    rep = hq // hkv
    scale = dh ** -0.5
    if q_offset is None:
        q_offset = sk - sq
    kv_valid = sk
    if sk % block_k:
        # ragged KV (e.g. 1601 vision tokens): zero-pad and mask the tail
        from repro.utils.padding import pad_to_multiple

        sk_pad = pad_to_multiple(sk, block_k)
        pad = ((0, 0), (0, 0), (0, sk_pad - sk), (0, 0))
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
        sk = sk_pad
    nb = sk // block_k

    qg = q.reshape(b, hkv, rep, sq, dh)
    kb = k.reshape(b, hkv, nb, block_k, dh)
    vb = v.reshape(b, hkv, nb, block_k, dh)

    qpos = q_offset + jnp.arange(sq)

    def step(carry, blk):
        m_prev, l_prev, acc = carry
        kj, vj, j = blk
        logits = (
            jnp.einsum("bgrsd,bgkd->bgrsk", qg, kj, preferred_element_type=jnp.float32)
            * scale
        )
        kpos = j * block_k + jnp.arange(block_k)
        mask = jnp.broadcast_to(kpos[None, :] < kv_valid, (sq, block_k))
        if causal:
            mask = mask & (kpos[None, :] <= qpos[:, None])
        if window is not None:
            mask = mask & (kpos[None, :] > qpos[:, None] - window)
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        m_cur = logits.max(-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(logits - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bgrsk,bgkd->bgrsd", p.astype(vj.dtype), vj,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, hkv, rep, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hkv, rep, sq), jnp.float32)
    acc0 = jnp.zeros((b, hkv, rep, sq, dh), jnp.float32)
    (m, denom, acc), _ = jax.lax.scan(
        step,
        (m0, l0, acc0),
        (jnp.moveaxis(kb, 2, 0), jnp.moveaxis(vb, 2, 0), jnp.arange(nb)),
    )
    out = acc / jnp.maximum(denom, 1e-30)[..., None]
    return out.reshape(b, hq, sq, dh).astype(q.dtype)


def banded_attention(q, k, v, *, window: int, block_k: int = 512):
    """Sliding-window prefill that only computes the diagonal band.

    Beyond-paper optimization (§Perf): for window w and block size bk, each
    q block of size bk attends to at most ceil(w/bk)+1 k blocks, so compute
    drops from O(S^2) to O(S*w).  q/k/v: as in ``blockwise_attention``;
    requires Sq == Sk and block-aligned shapes.
    """
    b, hq, s, dh = q.shape
    hkv = k.shape[1]
    rep = hq // hkv
    scale = dh ** -0.5
    assert s % block_k == 0
    nb = s // block_k
    nband = -(-window // block_k) + 1                 # k blocks per q block

    qg = q.reshape(b, hkv, rep, nb, block_k, dh)
    kb = k.reshape(b, hkv, nb, block_k, dh)
    vb = v.reshape(b, hkv, nb, block_k, dh)

    def per_qblock(i, qi):
        # gather the band of k/v blocks [nband, bk, dh] ending at block i
        idx = jnp.clip(i - (nband - 1) + jnp.arange(nband), 0, nb - 1)
        kj = jnp.take(kb, idx, axis=2)                # [b,hkv,nband,bk,dh]
        vj = jnp.take(vb, idx, axis=2)
        logits = (
            jnp.einsum("bgrsd,bgnkd->bgrsnk", qi, kj,
                       preferred_element_type=jnp.float32) * scale
        )
        qpos = i * block_k + jnp.arange(block_k)
        kpos = idx[:, None] * block_k + jnp.arange(block_k)[None, :]  # [nband, bk]
        mask = (kpos[None] <= qpos[:, None, None]) & (
            kpos[None] > qpos[:, None, None] - window
        )
        # clipped duplicate blocks (i < nband-1) are masked by position
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        p = jax.nn.softmax(
            logits.reshape(*logits.shape[:4], nband * block_k), axis=-1
        ).reshape(logits.shape)
        return jnp.einsum("bgrsnk,bgnkd->bgrsd", p.astype(vj.dtype), vj,
                          preferred_element_type=jnp.float32)

    out = jax.lax.map(
        lambda args: per_qblock(args[0], args[1]),
        (jnp.arange(nb), jnp.moveaxis(qg, 3, 0)),
    )                                                  # [nb, b, hkv, rep, bk, dh]
    out = jnp.moveaxis(out, 0, 3).reshape(b, hq, s, dh)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def softmax_cross_entropy(logits, labels, mask=None):
    """logits: [..., V] (any float dtype); labels: [...] int32.

    The gold logit is extracted with an iota-compare reduction rather than
    ``take_along_axis``: a gather over a vocab-sharded axis makes GSPMD
    all-gather the full logits (hundreds of GB at train_4k scale), while the
    masked reduction keeps the vocab axis sharded and lowers to a partial
    sum + tiny all-reduce.
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = (jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
              == labels[..., None])
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = logz - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
