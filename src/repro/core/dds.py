"""Directed Dynamic Snapshot (DDS) graph construction — paper §3.2.

Transforms a static bipartite order↔entity transaction graph into a directed
snapshot graph in which information flows strictly from the past:

1. ``order_t``     — effective order vertex, carries the label.
2. ``order_t^s``   — shadow clone; exchanges messages with same-snapshot
                     entities so *future* orders can see it as history, while
                     the effective order itself never feeds the graph.
3. ``entity_t``    — entity snapshot vertex, one per (entity, active snapshot).
4. Edges (paper Table 2):
   * ``order_t^s <-> entity_t``         (same snapshot, both directions)
   * ``entity_{t-i} -> entity_t``       (history + self-loop)
   * ``entity_{t-e} -> order_t``        (one edge per linked entity, from the
                                         entity's latest *strictly past*
                                         active snapshot — the only edges
                                         needed at online inference)

The construction guarantees the **no-future-leak invariant**: every directed
edge (u→v) satisfies snapshot(u) <= snapshot(v), and the only edges *into* an
effective order come from snapshots strictly in its past or — for the
same-snapshot entity state — only via entity self-history that itself never
saw the order.  Property-tested in ``tests/test_dds_properties.py``.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.graph import COOGraph, EdgeType, NodeType


@dataclass
class StaticGraph:
    """Host-side static transaction graph (paper §3.2 'Static Graph').

    ``edges`` is an [E, 2] int64 array of (order_id, entity_id); each order
    links at most one entity per entity *type* (shipping address, email, IP,
    device, phone, payment token, account — paper lists 7).
    """

    num_orders: int
    num_entities: int
    edges: np.ndarray              # [E, 2] (order, entity)
    order_snapshot: np.ndarray     # [O] int — snapshot index of checkout
    order_features: np.ndarray     # [O, F] float32 — raw checkout features
    labels: np.ndarray             # [O] {0,1} — unauthenticated chargeback
    entity_type: np.ndarray | None = None   # [num_entities] int — optional
    num_snapshots: int = field(default=0)

    def __post_init__(self):
        if self.num_snapshots == 0:
            self.num_snapshots = int(self.order_snapshot.max()) + 1 if self.num_orders else 0


@dataclass
class DDSGraph:
    """The DDS graph plus bookkeeping to map back to static ids."""

    coo: COOGraph
    # node-id layout: [0, O) effective orders; [O, 2O) shadows;
    # [2O, 2O + num_entity_snap_nodes) entity-snapshot vertices.
    num_orders: int
    entity_snap_ids: dict          # (entity, t) -> node id
    # the final-hop table (speed-layer input): for each order, the entity
    # snapshot node ids feeding its ENTITY_TO_ORDER edges
    last_hop: dict                 # order id -> list[(entity, t_e, node_id)]

    @property
    def shadow_offset(self) -> int:
        return self.num_orders


def build_dds(
    g: StaticGraph,
    entity_history: str = "all",
    max_history: int | None = None,
) -> DDSGraph:
    """Build the DDS graph from a static transaction graph.

    entity_history:
      * ``'all'``          — edge from every past active snapshot (paper default:
                             "entity_t may be connected with a bunch of
                             entity_{t-i}"), optionally capped at
                             ``max_history`` most recent.
      * ``'consecutive'``  — edge only from the previous active snapshot
                             (information still flows transitively; cheaper).
    Always adds the self-loop ``entity_t -> entity_t``.
    """
    if entity_history not in ("all", "consecutive"):
        raise ValueError(entity_history)
    O = g.num_orders

    # --- which (entity, t) pairs are active (linked to >= 1 order in t) ----
    order_of_edge = g.edges[:, 0]
    entity_of_edge = g.edges[:, 1]
    t_of_edge = g.order_snapshot[order_of_edge]

    pair_keys = entity_of_edge.astype(np.int64) * (g.num_snapshots + 1) + t_of_edge
    uniq_keys = np.unique(pair_keys)
    uniq_entity = uniq_keys // (g.num_snapshots + 1)
    uniq_t = uniq_keys % (g.num_snapshots + 1)
    entity_snap_ids: dict = {}
    for i, (ent, t) in enumerate(zip(uniq_entity.tolist(), uniq_t.tolist())):
        entity_snap_ids[(ent, t)] = 2 * O + i
    n_nodes = 2 * O + len(entity_snap_ids)

    # active snapshots per entity, sorted ascending
    active: dict = {}
    for ent, t in zip(uniq_entity.tolist(), uniq_t.tolist()):
        active.setdefault(ent, []).append(t)
    for ent in active:
        active[ent].sort()

    src, dst, et = [], [], []

    # --- shadow <-> entity (same snapshot) --------------------------------
    for o, ent, t in zip(order_of_edge.tolist(), entity_of_edge.tolist(), t_of_edge.tolist()):
        e_node = entity_snap_ids[(ent, t)]
        s_node = O + o  # shadow clone of order o
        src.append(s_node); dst.append(e_node); et.append(EdgeType.SHADOW_TO_ENTITY)
        src.append(e_node); dst.append(s_node); et.append(EdgeType.ENTITY_TO_SHADOW)

    # --- entity history (entity_{t-i} -> entity_t, incl. self loop) -------
    for ent, snaps in active.items():
        for j, t in enumerate(snaps):
            cur = entity_snap_ids[(ent, t)]
            src.append(cur); dst.append(cur); et.append(EdgeType.ENTITY_HIST)  # self-loop
            if entity_history == "consecutive":
                past = snaps[j - 1 : j] if j > 0 else []
            else:
                past = snaps[:j]
                if max_history is not None:
                    past = past[-max_history:]
            for tp in past:
                src.append(entity_snap_ids[(ent, tp)]); dst.append(cur); et.append(EdgeType.ENTITY_HIST)

    # --- effective entity -> order (the final 1-hop edges) ----------------
    last_hop: dict = {}
    for o, ent, t in zip(order_of_edge.tolist(), entity_of_edge.tolist(), t_of_edge.tolist()):
        snaps = active[ent]
        # latest active snapshot strictly before t  (paper: 0 <= t-e < t)
        idx = np.searchsorted(snaps, t) - 1
        if idx < 0:
            continue  # cold entity: no history before this order
        t_e = snaps[idx]
        e_node = entity_snap_ids[(ent, t_e)]
        src.append(e_node); dst.append(o); et.append(EdgeType.ENTITY_TO_ORDER)
        last_hop.setdefault(o, []).append((ent, t_e, e_node))

    # --- node tables -------------------------------------------------------
    F = g.order_features.shape[1]
    features = np.zeros((n_nodes, F), np.float32)
    features[:O] = g.order_features
    features[O : 2 * O] = g.order_features  # shadows share raw features
    # entity features are zero per paper §4.2 ("initial features set to zero")

    node_type = np.full(n_nodes, NodeType.ENTITY, np.int32)
    node_type[:O] = NodeType.ORDER
    node_type[O : 2 * O] = NodeType.SHADOW

    snapshot = np.zeros(n_nodes, np.int32)
    snapshot[:O] = g.order_snapshot
    snapshot[O : 2 * O] = g.order_snapshot
    for (ent, t), nid in entity_snap_ids.items():
        snapshot[nid] = t

    label = np.zeros(n_nodes, np.float32)
    label[:O] = g.labels
    label_mask = np.zeros(n_nodes, np.float32)
    label_mask[:O] = 1.0  # only effective orders are supervised

    coo = COOGraph(
        num_nodes=n_nodes,
        src=np.asarray(src, np.int64),
        dst=np.asarray(dst, np.int64),
        etype=np.asarray(et, np.int32),
        features=features,
        node_type=node_type,
        snapshot=snapshot,
        label=label,
        label_mask=label_mask,
    )
    return DDSGraph(coo=coo, num_orders=O, entity_snap_ids=entity_snap_ids, last_hop=last_hop)


def check_no_future_leak(dds: DDSGraph) -> None:
    """Assert the DDS invariants (used by property tests):

    1. every edge u->v has snapshot(u) <= snapshot(v);
    2. edges into an effective ORDER come only from strictly-past entity
       snapshots (EdgeType.ENTITY_TO_ORDER with snapshot(u) < snapshot(v));
    3. effective ORDER vertices have no outgoing edges (labels never leak);
    4. same-snapshot edges only connect shadows and entities.
    """
    coo = dds.coo
    s_snap = coo.snapshot[coo.src]
    d_snap = coo.snapshot[coo.dst]
    if not np.all(s_snap <= d_snap):
        raise AssertionError("edge from future snapshot found")
    into_order = coo.node_type[coo.dst] == NodeType.ORDER
    if into_order.any():
        if not np.all(coo.etype[into_order] == EdgeType.ENTITY_TO_ORDER):
            raise AssertionError("non-final-hop edge into effective order")
        if not np.all(s_snap[into_order] < d_snap[into_order]):
            raise AssertionError("same/future-snapshot edge into effective order")
    from_order = coo.node_type[coo.src] == NodeType.ORDER
    if from_order.any():
        raise AssertionError("effective order has outgoing edge (label leak)")
    same = s_snap == d_snap
    if same.any():
        ok_types = np.isin(
            coo.etype[same],
            [EdgeType.SHADOW_TO_ENTITY, EdgeType.ENTITY_TO_SHADOW, EdgeType.ENTITY_HIST],
        )
        if not np.all(ok_types):
            raise AssertionError("same-snapshot edge of illegal type")
