"""Lambda serving demo: the paper's production architecture, behind the one
typed serving API (``repro.service``).

Trains a small LNN, builds a ``FraudService`` in ``mode="batch"`` from a
single ``ServiceConfig`` artifact, then:
  1. BATCH LAYER — ``service.refresh`` pushes entity embeddings into the
     key-value store (one batched, model-version-stamped put per community);
  2. SPEED LAYER — a simulated checkout stream scored online through typed
     ``ScoreRequest``/``ScoreResponse`` (one KV lookup per linked entity,
     no graph traversal);
  3. proves the two-stage scores equal the monolithic GNN forward, and
     reports the latency gap plus the service's structured stats.

Run:  PYTHONPATH=src python examples/lambda_serving.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import LNNConfig
from repro.data import (SynthConfig, build_communities, generate_transactions,
                        make_split_masks)
from repro.data.pipeline import standardize_features
from repro.serve import history_requests
from repro.service import FraudService, ModelSection, ServiceConfig
from repro.train.loop import train_lnn


def main():
    g, _ = generate_transactions(SynthConfig(num_users=300, num_rings=5,
                                             feature_noise=0.8, seed=1))
    split = make_split_masks(g.order_snapshot)
    feats, _ = standardize_features(g.order_features, split == 0)
    g.order_features = feats
    batches = build_communities(g, community_size=256, max_deg=24)
    cfg = LNNConfig(num_gnn_layers=3, hidden_dim=64, feat_dim=feats.shape[1],
                    pos_weight=3.0)
    print("== training a small LNN ==")
    res = train_lnn(batches, split, cfg, epochs=15, patience=5)

    # ONE artifact describes the whole service; save/load it like a model
    config = ServiceConfig(mode="batch",
                           model=ModelSection.from_lnn_config(cfg))
    print("\n== building the FraudService from one ServiceConfig artifact ==")
    svc = FraudService(config, params=res.params).build().warmup()
    print(f"   lifecycle state: {svc.state}  (build -> warmup -> serve)")

    print("\n== batch layer: periodic entity-embedding refresh ==")
    stats = svc.refresh(batches)
    print(f"   wrote {stats['entities_written']} entity embeddings "
          f"in {stats['seconds']:.2f}s -> KV store size {stats['store_size']}")

    print("\n== correctness: two-stage == monolithic ==")
    worst = svc.score_equivalence_check(batches)
    print(f"   max |online - full forward| = {worst:.2e}")

    print("\n== speed layer: scoring a checkout stream ==")
    requests = history_requests(batches)[:300]
    svc.score(requests[:1])   # warm jit
    lat = []
    risky = 0
    for r in requests:
        t0 = time.time()
        resp = svc.score([r])[0]
        lat.append((time.time() - t0) * 1e3)
        risky += resp.score > 0.5
    lat = np.asarray(lat)
    print(f"   {len(requests)} checkouts, {risky} flagged risky")
    print(f"   latency p50={np.percentile(lat, 50):.2f}ms "
          f"p95={np.percentile(lat, 95):.2f}ms p99={np.percentile(lat, 99):.2f}ms")
    st = svc.stats()
    print(f"   service stats: {st.scored} scored under model v{st.model_version}, "
          f"KV {st.store_stats}")
    svc.drain()
    svc.close()
    print(f"   closed cleanly (state: {svc.state})")


if __name__ == "__main__":
    main()
