from repro.baselines.gbdt import GBDTConfig, GBDTModel, train_gbdt
from repro.baselines.mlp import MLPConfig, mlp_init, mlp_forward, train_mlp

__all__ = [
    "GBDTConfig",
    "GBDTModel",
    "train_gbdt",
    "MLPConfig",
    "mlp_init",
    "mlp_forward",
    "train_mlp",
]
