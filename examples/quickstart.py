"""Quickstart: the paper end-to-end in ~2 minutes on CPU.

Generates a synthetic e-commerce transaction stream with fraud rings,
builds the DDS graph per community, trains the LNN fraud detector for a few
hundred community steps, and compares against the LightGBM-style baseline —
reproducing the paper's Table-3 ordering.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.baselines import GBDTConfig, train_gbdt
from repro.core import LNNConfig
from repro.data import (SynthConfig, build_communities, generate_transactions,
                        make_split_masks)
from repro.data.pipeline import standardize_features
from repro.train.loop import evaluate_lnn, train_lnn
from repro.train.metrics import binary_metrics


def main():
    # 1. data: months of checkouts, entities shared inside fraud rings
    print("== generating transactions ==")
    g, _ = generate_transactions(SynthConfig(num_users=400, num_rings=6,
                                             feature_noise=0.8, seed=0))
    split = make_split_masks(g.order_snapshot)           # 80/10/10 by time
    feats, _ = standardize_features(g.order_features, split == 0)
    print(f"   {g.num_orders} orders, {g.num_entities} entities, "
          f"fraud rate {g.labels.mean():.3f}")

    # 2. tabular baseline (the paper's LGB)
    print("== training GBDT baseline ==")
    gbdt = train_gbdt(feats[split == 0], g.labels[split == 0], GBDTConfig(),
                      feats[split == 1], g.labels[split == 1])
    m = binary_metrics(g.labels[split == 2], gbdt.predict_proba(feats[split == 2]))
    print(f"   LGB   test: AUC={m['roc_auc']:.4f} AP={m['average_precision']:.4f}")

    # 3. LGB-encoded features feed the LNN (paper §4.2)
    enc = np.concatenate([feats, gbdt.leaf_value_features(feats)], 1)
    mu, sd = enc[split == 0].mean(0), enc[split == 0].std(0) + 1e-6
    g.order_features = ((enc - mu) / sd).astype(np.float32)

    # 4. partition -> per-community DDS graphs (no future information flow)
    print("== building DDS communities ==")
    batches = build_communities(g, community_size=256, max_deg=24)
    print(f"   {len(batches)} communities, padded to "
          f"{batches[0].graph.num_nodes} nodes each")

    # 5. train the LNN end-to-end (stage1 ∘ stage2)
    print("== training LNN(GCN) ==")
    cfg = LNNConfig(gnn_type="gcn", num_gnn_layers=3, hidden_dim=64,
                    feat_dim=g.order_features.shape[1], pos_weight=3.0)
    res = train_lnn(batches, split, cfg, epochs=40, patience=8, verbose=True)
    m2 = evaluate_lnn(res.params, cfg, batches, split, 2)
    print(f"   LNN   test: AUC={m2['roc_auc']:.4f} AP={m2['average_precision']:.4f}")
    print(f"\ngraph lift: +{(m2['roc_auc']-m['roc_auc'])*100:.2f} AUC pts, "
          f"+{(m2['average_precision']-m['average_precision'])*100:.2f} AP pts "
          f"over the tabular baseline (paper Table 3's qualitative claim)")


if __name__ == "__main__":
    main()
