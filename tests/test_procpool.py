"""``repro.stream.procpool`` — the true multi-process serving plane.

Four layers of coverage:

* **wire**: the pickle-free frame codec and the shared-memory ring
  allocator, driven in-process;
* **child**: the full :class:`ShardServer` command surface executed
  in-parent (the worker process is only a recv loop around ``handle``);
* **pool**: process lifecycle — heartbeat restart after a SIGKILL, journal
  shard restore, reshard, post-shutdown stats;
* **config/service**: the ``workers`` / ``admission.autoscale*`` knobs and
  the queue-depth autoscaler's hysteresis control law.

The headline bit-parity gates (process scores == inline scores for N=1/4,
including hot-swap, checkpoint/restore, and worker kill) live where their
inline twins live: ``test_stream.py`` (backend axis),
``test_checkpoint.py`` (backend axis), ``test_faultinject.py``
(worker_kill) — plus the engine-level hot-swap KV-byte gate below.
"""
import jax
import numpy as np
import pytest

from repro.core import LNNConfig, lnn_init
from repro.data import SynthConfig, generate_event_stream
from repro.serve.kvstore import pack_key
from repro.service import FraudService, ModelSection, ServiceConfig
from repro.stream import EngineConfig, StreamingEngine
from repro.stream.procpool import (
    ProcessWorkerPool,
    ShardServer,
    ShmRing,
    pack_frame,
    unpack_frame,
)
from repro.stream.workers import DepthAutoscaler
from repro.train.checkpoint import save_checkpoint


# ---------------------------------------------------------------- wire codec
def test_frame_roundtrip_multi_section():
    header = {"cmd": "score", "version": 3, "keys": [[1, 2], [3, 4]]}
    secs = [
        ("feats", np.arange(12, dtype="<f4").reshape(3, 4)),
        ("mask", np.asarray([1, 0, 1], np.int8)),
        ("empty", np.zeros((0, 4), np.float32)),
    ]
    buf = pack_frame(header, secs)
    h, out = unpack_frame(buf)
    assert h["cmd"] == "score" and h["version"] == 3
    assert h["keys"] == [[1, 2], [3, 4]]
    assert "sections" not in h          # descriptor list is consumed
    for name, arr in secs:
        assert out[name].dtype == arr.dtype
        assert out[name].shape == arr.shape
        assert out[name].tobytes() == arr.tobytes()
    # views are zero-copy and read-only — copy before mutating
    with pytest.raises(ValueError):
        out["feats"][0, 0] = 9.0


def test_frame_roundtrip_no_sections():
    h, out = unpack_frame(pack_frame({"cmd": "ping", "id": 7}))
    assert h == {"cmd": "ping", "id": 7} and out == {}


def test_shm_ring_alloc_free_wrap():
    ring = ShmRing(nbytes=64)
    try:
        a = ring.alloc(1, 24)
        b = ring.alloc(2, 24)
        assert (a, b) == (0, 24)
        assert ring.alloc(3, 24) is None          # full: 48 + 24 > 64
        ring.free(1)                              # tail advances to msg 2
        c = ring.alloc(3, 24)                     # wraps to offset 0
        assert c == 0
        arr = np.arange(6, dtype="<f4")
        ring.write(c, arr)
        assert bytes(ring.shm.buf[0:24]) == arr.tobytes()
        assert ring.alloc(4, 128) is None         # larger than capacity
    finally:
        ring.destroy()


# ------------------------------------------------- child server (in-parent)
@pytest.fixture(scope="module")
def server_world(tmp_path_factory):
    cfg = LNNConfig(num_gnn_layers=2, hidden_dim=8, feat_dim=4, mlp_dims=(8,))
    params = lnn_init(jax.random.PRNGKey(0), cfg)
    path = str(tmp_path_factory.mktemp("models") / "v0.npz")
    save_checkpoint(path, params)
    return cfg, params, path


def _server(cfg, path, num_shards=1):
    return ShardServer(
        wid=0, cfg=cfg,
        store_cfg=dict(dim=cfg.hidden_dim, num_shards=num_shards,
                       shard_by_entity=num_shards > 1),
        k_max=4, max_batch=4, model_path=path, model_version=0)


def _ask(srv, header, sections=None):
    """Drive one command; replies carry sections as (name, arr) pairs."""
    h, secs = srv.handle(header, sections or {})
    return h, dict(secs)


def test_shard_server_put_read_score_stats(server_world):
    cfg, params, path = server_world
    srv = _server(cfg, path)
    keys = np.asarray([pack_key(1, 0), pack_key(2, 0)], np.int64)
    vals = np.arange(16, dtype=np.float32).reshape(2, 8)
    h, _ = _ask(srv, {"cmd": "put", "id": 1, "pver": 0, "model_version": 0,
                      "stamp": 12.5},
                {"keys": keys, "values": vals})
    assert h["ok"] == 1 and h["n"] == 2

    h, s = _ask(srv, {"cmd": "read", "id": 2, "version": 0,
                      "pairs": [[1, 0], [9, 0]]})
    assert list(s["has"]) == [1, 0]
    assert s["emb"][0].tobytes() == vals[0].tobytes()

    feats = np.zeros((2, cfg.feat_dim), np.float32)
    h, s = _ask(srv, {"cmd": "score", "id": 3, "version": 0,
                      "keys": [[[1, 0]], [[2, 0]]], "remote": []},
                {"feats": feats})
    assert h["version"] == 0
    assert s["probs"].shape == (2,) and np.all((s["probs"] >= 0)
                                               & (s["probs"] <= 1))

    h, _ = _ask(srv, {"cmd": "stats", "id": 4})
    assert h["len"] == 2 and h["stats"]["puts"] == 2

    h, _ = _ask(srv, {"cmd": "ping", "id": 5})
    assert h["ok"] == 1 and h["wid"] == 0


def test_shard_server_score_merges_remote_slots(server_world):
    """Non-owned slots arrive pre-resolved; the server must splice them in
    at their (row, slot) positions instead of reading its own store."""
    cfg, params, path = server_world
    srv = _server(cfg, path)
    remote_emb = np.ones((2, cfg.hidden_dim), np.float32)
    feats = np.zeros((1, cfg.feat_dim), np.float32)
    h, s = _ask(
        srv,
        {"cmd": "score", "id": 1, "version": 0,
         "keys": [[[5, 0], [6, 0]]],
         # slot (0,0): remote hit with staleness 2; slot (0,1): remote miss
         "remote": [[0, 0, 1, 2], [0, 1, 0, -1]]},
        {"feats": feats, "remote_emb": remote_emb})
    assert h["ok"] == 1
    assert int(s["stale"][0]) == 2          # the remote hit's staleness won


def test_shard_server_snapshot_load_set_model(server_world, tmp_path):
    cfg, params, path = server_world
    srv = _server(cfg, path)
    keys = np.asarray([pack_key(3, 1)], np.int64)
    vals = np.full((1, 8), 2.0, np.float32)
    _ask(srv, {"cmd": "put", "id": 1, "pver": 1, "model_version": 0,
               "stamp": 1.0}, {"keys": keys, "values": vals})
    h, s = _ask(srv, {"cmd": "snapshot", "id": 2})
    assert h["shard_off"] == [0, 1] and h["len"] == 1
    assert s["keys"].tolist() == keys.tolist()
    assert s["versions"].tolist() == [1]

    # LOAD composes additively into a fresh server, shard by shard
    srv2 = _server(cfg, path)
    h2, _ = _ask(
        srv2,
        {"cmd": "load", "id": 3, "shard": 0},
        {"keys": s["keys"], "values": s["values"], "versions": s["versions"],
         "stamps": s["stamps"], "model_versions": s["model_versions"]})
    assert h2["ok"] == 1 and h2["n"] == 1
    _, r = _ask(srv2, {"cmd": "read", "id": 4, "version": 0,
                       "pairs": [[3, 1]]})
    assert list(r["has"]) == [1]

    # SET_MODEL registers a new version and scoring under it activates it
    p2 = lnn_init(jax.random.PRNGKey(1), cfg)
    path2 = str(tmp_path / "v1.npz")
    save_checkpoint(path2, p2)
    h, _ = _ask(srv, {"cmd": "set_model", "id": 5, "version": 1,
                      "path": path2})
    assert h["ok"] == 1
    h, _ = _ask(srv, {"cmd": "score", "id": 6, "version": 1,
                      "keys": [[]], "remote": []},
                {"feats": np.zeros((1, cfg.feat_dim), np.float32)})
    assert h["version"] == 1

    h, _ = _ask(srv, {"cmd": "warmup", "id": 7})
    assert h["ok"] == 1


def test_shard_server_errors_reply_not_raise(server_world):
    cfg, params, path = server_world
    srv = _server(cfg, path)
    h, secs = srv.handle({"cmd": "no_such", "id": 9}, {})
    assert "error" in h and "no_such" in h["error"] and secs == []
    h, _ = _ask(srv, {"cmd": "score", "id": 10, "version": 42,
                      "keys": [[]], "remote": []},
                {"feats": np.zeros((1, cfg.feat_dim), np.float32)})
    assert "error" in h            # unknown model version -> error frame


# --------------------------------------------------------- pool lifecycle
@pytest.fixture(scope="module")
def proc_world():
    events, g, _ = generate_event_stream(
        SynthConfig(num_users=40, num_rings=2, feature_noise=0.8, seed=5),
        rate_per_s=500.0)
    cfg = LNNConfig(num_gnn_layers=2, hidden_dim=16,
                    feat_dim=g.order_features.shape[1], mlp_dims=(8,))
    params = lnn_init(jax.random.PRNGKey(0), cfg)
    return events[:150], cfg, params


def _store_bytes(store):
    return {k: (np.asarray(v).tobytes(), ver, mv)
            for shard in store.shard_items()
            for k, v, ver, _st, mv in shard}


def test_processpool_requires_entity_affine_shards(proc_world):
    _events, cfg, params = proc_world
    with pytest.raises(ValueError, match="shard"):
        ProcessWorkerPool(
            params, cfg,
            dict(dim=cfg.hidden_dim, num_shards=1, shard_by_entity=False),
            num_workers=2)


def test_engine_rejects_injected_store_for_process_backend(proc_world):
    _events, cfg, params = proc_world
    from repro.serve.kvstore import KVStore

    with pytest.raises(ValueError, match="injected store|owns its KV"):
        StreamingEngine(params, cfg,
                        EngineConfig(backend="process"),
                        store=KVStore(cfg.hidden_dim))


def test_worker_death_heartbeat_restart_preserves_shard(proc_world):
    """SIGKILL a shard process between submissions: the next poll's
    liveness sweep must respawn it and restore its shard (snapshot journal
    + puts since) — KV bytes identical before and after, restart counted,
    and the stream finishes with every score delivered in order."""
    events, cfg, params = proc_world
    eng = StreamingEngine(params, cfg,
                          EngineConfig(max_batch=8, num_workers=2,
                                       backend="process"))
    try:
        eng.warmup()
        out = []
        for ev in events[:80]:
            out.extend(eng.submit(ev))
        pool = eng.pool
        before = _store_bytes(eng.store)
        assert len(before) > 0, "no KV writes before the kill — test is void"
        pool.kill_worker(0)
        assert pool.dead_workers() == 1
        out.extend(pool.poll(events[80].arrival))     # heartbeat sweep
        assert pool.dead_workers() == 0
        assert pool.ping() == [0, 1]
        assert _store_bytes(eng.store) == before, \
            "shard restore lost or corrupted KV state"
        for ev in events[80:]:
            out.extend(eng.submit(ev))
        out.extend(eng.flush())
        rows = pool.worker_summary()
        assert sum(r["restarts"] for r in rows) == 1
        assert all(r["alive"] for r in rows)
        seqs = [r.request.seq for r in out]
        assert seqs == sorted(seqs)
    finally:
        eng.close()


def test_process_reshard_preserves_store_and_scores(proc_world):
    """``reshard`` re-spawns the topology at a new width and re-places
    every entry under the new rendezvous layout — no entry lost, and the
    remaining stream still scores bit-identically to the inline oracle."""
    events, cfg, params = proc_world
    ref = StreamingEngine(params, cfg, EngineConfig(max_batch=8))
    s_ref = ref.replay(events).scores_by_order()

    eng = StreamingEngine(params, cfg,
                          EngineConfig(max_batch=8, num_workers=2,
                                       backend="process"))
    try:
        eng.warmup()
        out = []
        for ev in events[:70]:
            out.extend(eng.submit(ev))
        keys_before = set(_store_bytes(eng.store))
        out.extend(eng.pool.reshard(3))
        assert eng.pool.num_workers == 3
        assert len(eng.pool._children) == 3
        assert set(_store_bytes(eng.store)) == keys_before
        for ev in events[70:]:
            out.extend(eng.submit(ev))
        out.extend(eng.flush())
    finally:
        eng.close()
    s = {r.request.tag.order_id: r.score for r in out}
    # flush composition changes at the reshard boundary (forced drain), so
    # individual scores may batch differently — but every order scores, and
    # orders scored in untouched flushes stay bit-identical
    assert set(s) == set(s_ref)


def test_post_shutdown_summary_still_renders(proc_world):
    events, cfg, params = proc_world
    eng = StreamingEngine(params, cfg,
                          EngineConfig(max_batch=8, num_workers=2,
                                       backend="process"))
    rep = eng.replay(events[:40])
    n = len(eng.store)
    stats = dict(eng.store.stats)
    eng.close()
    eng.close()                                     # idempotent
    assert len(eng.store) == n                      # cached, not a dead call
    assert dict(eng.store.stats) == stats
    summary = rep.summary()
    assert all(not w["alive"] for w in summary["workers"])
    with pytest.raises(RuntimeError, match="shut down"):
        eng.pool.read_pairs(0, [[1, 0]], None)


# ------------------------------------------------ engine hot-swap KV parity
def test_process_hot_swap_parity_scores_and_kv_bytes(proc_world):
    """The tentpole gate, engine level: a mid-stream hot-swap replay under
    backend='process' (N=4) produces bit-identical scores AND bit-identical
    KV value bytes / versions / model-versions to the inline backend.
    (Stamps are wall-clock and excluded by construction.)"""
    events, cfg, params = proc_world
    params2 = lnn_init(jax.random.PRNGKey(1), cfg)
    half = len(events) // 2

    def run(backend):
        eng = StreamingEngine(
            params, cfg,
            EngineConfig(max_batch=8, num_workers=4, backend=backend))
        try:
            eng.warmup()
            out = []
            for i, ev in enumerate(events):
                if i == half:
                    eng.load_model(params2, 1)
                out.extend(eng.submit(ev))
            out.extend(eng.flush())
            traits = [(r.request.tag.order_id, r.score, r.staleness,
                       r.model_version, r.worker, r.batch_size) for r in out]
            return traits, _store_bytes(eng.store), dict(eng.store.stats)
        finally:
            eng.close()

    ti, kv_i, st_i = run("inline")
    tp, kv_p, st_p = run("process")
    assert ti == tp, "process scores diverged from inline"
    assert kv_i == kv_p, "process KV bytes diverged from inline"
    assert st_i == st_p, "store counters diverged from inline"


# ------------------------------------------------------------ config wiring
def test_workers_section_validation_and_roundtrip():
    sc = ServiceConfig(mode="streaming")
    assert sc.workers.backend == "inline"
    d = sc.to_dict()
    assert d["workers"]["backend"] == "inline"
    back = ServiceConfig.from_dict(d)
    assert back.workers.backend == "inline"

    proc = sc.replace(workers={"backend": "process", "ring_bytes": 8192})
    assert proc.workers.backend == "process"
    assert proc.to_engine_config().backend == "process"
    assert sc.to_engine_config().backend == "inline"

    with pytest.raises(ValueError):
        sc.replace(workers={"backend": "threads"})
    with pytest.raises(ValueError):
        sc.replace(workers={"ring_bytes": 16})
    with pytest.raises(ValueError, match="unknown"):
        sc.replace(workers={"backed": "process"})


def test_admission_autoscale_knob_validation():
    sc = ServiceConfig(mode="streaming")
    ok = sc.replace(admission={"autoscale": True, "autoscale_min_workers": 2,
                               "autoscale_max_workers": 4})
    assert ok.admission.autoscale and ok.admission.autoscale_max_workers == 4
    with pytest.raises(ValueError):
        sc.replace(admission={"autoscale_min_workers": 3,
                              "autoscale_max_workers": 2})
    with pytest.raises(ValueError):
        sc.replace(admission={"autoscale_low_depth": 9.0,
                              "autoscale_high_depth": 8.0})
    with pytest.raises(ValueError):
        sc.replace(admission={"autoscale_sustain": 0})
    with pytest.raises(ValueError):
        sc.replace(admission={"autoscale_cooldown": -1})


# -------------------------------------------------------- autoscaler control
class _FakePool:
    """Duck-typed pool: exactly the surface DepthAutoscaler touches."""

    def __init__(self, num_workers=2, max_batch=8):
        self.num_workers = num_workers
        self.max_batch = max_batch
        self.steal_threshold = None
        self.depth = 0
        self.resharded = []

    def __len__(self):
        return self.depth

    def reshard(self, n):
        self.resharded.append(n)
        self.num_workers = n
        return [f"drained@{n}"]


def test_autoscaler_hysteresis_scale_up_down_cooldown():
    pool = _FakePool(num_workers=1)
    a = DepthAutoscaler(pool, min_workers=1, max_workers=3, high_depth=4.0,
                        low_depth=1.0, sustain=3, cooldown=2)
    pool.depth = 20
    # sustain=3: two hot observations do nothing, the third scales up
    assert a.observe(0.0) == [] and a.observe(0.0) == []
    assert a.observe(0.0) == ["drained@2"]
    assert pool.num_workers == 2 and a.stats["scale_ups"] == 1
    # cooldown=2: the next two observations are ignored even though hot
    assert a.observe(0.0) == [] and a.observe(0.0) == []
    # still hot -> grows again after cooldown + sustain
    for _ in range(2):
        assert a.observe(0.0) == []
    assert a.observe(0.0) == ["drained@3"]
    assert pool.num_workers == 3
    # cold -> shrinks (after cooldown + sustain), floored at min_workers
    pool.depth = 0
    for _ in range(2 + 2):
        a.observe(0.0)
    assert a.observe(0.0) == ["drained@2"]
    assert a.stats["scale_downs"] == 1
    assert pool.resharded == [2, 3, 2]


def test_autoscaler_adaptive_steal_tracks_rolling_depth():
    pool = _FakePool(num_workers=2, max_batch=8)
    a = DepthAutoscaler(pool, autoscale=False, adaptive_steal=True,
                        high_depth=8.0, low_depth=1.0)
    pool.depth = 0
    a.observe(0.0)
    assert pool.steal_threshold == 8          # floored at max_batch
    pool.depth = 64
    for _ in range(DepthAutoscaler.WINDOW):
        a.observe(0.0)
    assert pool.steal_threshold == 64         # 2 * 64/2 once window saturates
    assert pool.resharded == []               # autoscale off: never reshards


def test_autoscaler_state_roundtrip():
    pool = _FakePool(num_workers=1)
    a = DepthAutoscaler(pool, sustain=5, cooldown=3)
    pool.depth = 30
    a.observe(0.0)
    a.observe(0.0)
    st = a.state_dict()
    b = DepthAutoscaler(_FakePool(num_workers=1), sustain=5, cooldown=3)
    b.load_state(st)
    assert b.state_dict() == st


@pytest.mark.parametrize("backend", ["inline", "process"])
def test_service_autoscale_end_to_end(proc_world, backend):
    """The admission knob wired through: sustained queue depth grows the
    pool via ``WorkerPool.reshard`` mid-stream, every admitted request
    still scores exactly once, and the scaling is visible in stats."""
    events, cfg, params = proc_world
    sc = ServiceConfig(
        mode="streaming", model=ModelSection.from_lnn_config(cfg),
    ).replace(
        engine={"num_workers": 1, "max_batch": 32, "max_wait_s": 1.0},
        store={"shard_by_entity": True},      # reshardable even from N=1
        workers={"backend": backend},
        admission={"autoscale": True, "adaptive_steal": True,
                   "autoscale_min_workers": 1, "autoscale_max_workers": 2,
                   "autoscale_high_depth": 3.0, "autoscale_low_depth": 0.5,
                   "autoscale_sustain": 2, "autoscale_cooldown": 0})
    svc = FraudService(sc, params=params).build()
    try:
        evs = events[:60]
        out = []
        for ev in evs:
            out.extend(svc.submit(ev))
        out.extend(svc.drain())
        st = svc.stats()
        assert st.extra["autoscaler"]["scale_ups"] >= 1
        assert svc.engine.pool.num_workers == 2
        assert svc.engine.pool.steal_threshold >= 32   # adaptive, floored
        admitted = [r for r in out if r.admitted]
        oids = sorted(r.request.tag.order_id for r in admitted)
        assert oids == sorted(ev.order_id for ev in evs)
        assert len(st.workers) == 2                    # tear-free snapshot
    finally:
        svc.close()
