"""Unit coverage for the ``repro.learn`` plane's mechanisms: the WAL
training tap (receptive cones, delayed-label join, compaction pins), the
rolling-window policy and local optimizers, scheduled checkpointing with
retention, the shared rollback path, and the gateway's learn endpoints.

The promotion state machine and the end-to-end closed loop live in
``tests/test_learn_promotion.py``.
"""
import json
import urllib.error
import urllib.request

import jax
import numpy as np
import pytest

from repro.core import LNNConfig, lnn_init
from repro.data import SynthConfig, generate_event_stream
from repro.learn import (LabelLog, RollingWindowTrainer, TrainingExample,
                         WalTrainingTap, WindowPolicy, adam, recall_at_budget,
                         sgd)
from repro.models.hybrid import HybridModel
from repro.service import (FraudService, ModelSection, ServiceConfig,
                           ServiceLifecycleError)
from repro.stream.checkpoint import (WriteAheadLog, list_checkpoints,
                                     prune_checkpoints)
from repro.stream.events import CheckoutEvent


def _ev(i, snapshot=0, entities=(1, 2), label=0.0, feats=None):
    f = np.asarray([0.5, -0.25] if feats is None else feats, np.float32)
    return CheckoutEvent(order_id=i, snapshot=snapshot,
                         entities=tuple(entities), features=f,
                         label=float(label), arrival=0.01 * i)


# ------------------------------------------------------------------ WAL tap
def test_tap_emits_examples_with_strictly_past_cones(tmp_path):
    wal = WriteAheadLog(str(tmp_path / "wal.jsonl"))
    wal.append_event("submit", _ev(0, snapshot=0, entities=(7, 8)))
    wal.append_event("submit", _ev(1, snapshot=1, entities=(7, 9)))
    wal.append_model(1, "models/v1.npz")     # non-event records are skipped
    wal.append_event("ingest", _ev(2, snapshot=2, entities=(8, 9)))
    with WalTrainingTap(wal, feat_dim=2) as tap:
        out = tap.poll()
        assert [ex.order_id for ex in out] == [0, 1, 2]
        assert [ex.seq for ex in out] == [1, 2, 4]
        # order 0 links only cold entities: its cone must be empty (the key
        # list is computed BEFORE add_order — no self-leak)
        assert out[0].entity_keys == ()
        # order 1 sees entity 7's snapshot-0 state, never its own snapshot
        assert out[1].entity_keys == ((7, 0),)
        assert out[2].entity_keys == ((8, 0), (9, 1))
        assert all(t < ex.snapshot
                   for ex in out for (_e, t) in ex.entity_keys)
        assert tap.cursor == wal.last_seq
        assert tap.stats["skipped"] == 1
        # idempotent: nothing new -> nothing emitted
        assert tap.poll() == []
    wal.close()


def test_tap_include_ingest_off(tmp_path):
    wal = WriteAheadLog(str(tmp_path / "wal.jsonl"))
    wal.append_event("submit", _ev(0))
    wal.append_event("ingest", _ev(1))
    with WalTrainingTap(wal, feat_dim=2, include_ingest=False) as tap:
        assert [ex.order_id for ex in tap.poll()] == [0]
        assert tap.stats["skipped"] == 1
    wal.close()


def test_label_log_join_overrides_event_label(tmp_path):
    wal = WriteAheadLog(str(tmp_path / "wal.jsonl"))
    for i in range(3):
        wal.append_event("submit", _ev(i, label=0.0))
    log = LabelLog()
    with WalTrainingTap(wal, feat_dim=2, label_log=log,
                        label_latency_s=10.0) as tap:
        assert tap.poll(now=0.1) == []          # window open, all pending
        assert tap.pending == 3
        log.record(1, 1.0)                      # chargeback lands for order 1
        out = tap.poll(now=0.1)                 # released early by the join
        assert [ex.order_id for ex in out] == [1]
        assert out[0].label == 1.0 and out[0].label_source == "label_log"
        out = tap.poll(now=100.0)               # the rest expire
        assert sorted(ex.order_id for ex in out) == [0, 2]
        assert all(ex.label == 0.0 and ex.label_source == "event"
                   for ex in out)
        assert tap.stats["label_joins"] == 1
        assert tap.stats["label_defaults"] == 2
        assert tap.pending == 0
    wal.close()


def test_tap_rejects_negative_latency(tmp_path):
    wal = WriteAheadLog(str(tmp_path / "wal.jsonl"))
    with pytest.raises(ValueError, match="label_latency_s"):
        WalTrainingTap(wal, feat_dim=2, label_latency_s=-1.0)
    wal.close()


# -------------------------------------------------- compaction-vs-reader race
def test_compact_respects_pins(tmp_path):
    """The WAL-compaction vs. training-tap race: a pin at the reader's
    cursor clamps ``compact()`` so unread records can never be deleted."""
    wal = WriteAheadLog(str(tmp_path / "wal.jsonl"))
    for i in range(10):
        wal.append_event("submit", _ev(i))
    pin = wal.pin(3)                     # reader consumed seqs 1..3
    assert wal.min_pinned() == 3
    # a checkpoint wants to truncate through seq 10 — the pin clamps it
    wal.compact(10)
    assert [r["seq"] for r in wal.scan()] == [4, 5, 6, 7, 8, 9, 10]
    # the lagging reader can still consume its suffix
    assert len(list(wal.scan(after_seq=3))) == 7
    with pytest.raises(ValueError, match="only advance"):
        wal.move_pin(pin, 2)             # pins are monotonic
    wal.move_pin(pin, 8)
    wal.compact(10)
    assert [r["seq"] for r in wal.scan()] == [9, 10]
    wal.unpin(pin)
    wal.unpin(pin)                       # idempotent
    assert wal.min_pinned() is None
    wal.compact(10)
    assert list(wal.scan()) == []
    wal.close()


def test_tap_pins_survive_interleaved_compaction(tmp_path):
    """A tap that polls between compactions loses nothing: every submit
    record is emitted exactly once even when compaction runs concurrently
    behind its cursor."""
    wal = WriteAheadLog(str(tmp_path / "wal.jsonl"))
    with WalTrainingTap(wal, feat_dim=2) as tap:
        seen = []
        for i in range(12):
            wal.append_event("submit", _ev(i))
            if i % 3 == 2:
                wal.compact(wal.last_seq)   # clamped at the tap's pin
                seen += [ex.order_id for ex in tap.poll()]
        seen += [ex.order_id for ex in tap.poll()]
        assert seen == list(range(12))
    wal.close()


# ----------------------------------------------------------- window + optim
def test_window_policy_validation():
    with pytest.raises(ValueError, match="min_window"):
        WindowPolicy(min_window=0)
    with pytest.raises(ValueError, match="max_window"):
        WindowPolicy(min_window=8, max_window=4)
    with pytest.raises(ValueError, match="stride"):
        WindowPolicy(stride=0)
    with pytest.raises(ValueError, match="stride"):
        WindowPolicy(max_window=64, stride=65)


@pytest.mark.parametrize("make", [sgd, adam])
def test_local_optimizers_descend_quadratic(make):
    """Both local optimizers minimize 0.5*||w||^2 (grad = w) — no optax."""
    init_fn, update_fn = make(0.1)
    params = {"w": np.asarray([4.0, -3.0], np.float32)}
    state = init_fn(params)
    norms = [float(np.linalg.norm(params["w"]))]
    for _ in range(50):
        grads = {"w": params["w"]}
        params, state = update_fn(grads, state, params)
        norms.append(float(np.linalg.norm(params["w"])))
    assert norms[-1] < 0.25 * norms[0]
    assert all(b <= a + 1e-6 for a, b in zip(norms, norms[1:]))


def test_trainer_rejects_bad_knobs():
    cfg = LNNConfig(num_gnn_layers=2, hidden_dim=4, feat_dim=2)
    with pytest.raises(ValueError, match="optimizer"):
        RollingWindowTrainer(cfg, optimizer="lbfgs")
    with pytest.raises(ValueError, match="head"):
        RollingWindowTrainer(cfg, head="transformer")
    with pytest.raises(ValueError, match="steps"):
        RollingWindowTrainer(cfg, steps=0)


def _tap_ex(i, *, order_id=None, seq=None, label=0.0, snapshot=0):
    rng = np.random.default_rng(i)
    return TrainingExample(
        order_id=i if order_id is None else order_id, snapshot=snapshot,
        entities=(100 + i % 5, 200 + i % 3),
        features=rng.normal(0, 1, 6).astype(np.float32),
        label=label, arrival=0.01 * i, seq=i + 1 if seq is None else seq)


def test_trainer_ready_follows_stride():
    cfg = LNNConfig(num_gnn_layers=2, hidden_dim=4, feat_dim=6)
    tr = RollingWindowTrainer(
        cfg, WindowPolicy(min_window=4, max_window=8, stride=3), steps=1)
    for i in range(3):
        tr.add(_tap_ex(i))
    assert not tr.ready()                 # below min_window
    tr.add(_tap_ex(3))
    assert tr.ready()                     # first fire needs no stride
    tr.train(lnn_init(jax.random.PRNGKey(0), cfg))
    assert not tr.ready()                 # stride of fresh examples required
    tr.extend(_tap_ex(i) for i in range(4, 6))
    assert not tr.ready()
    tr.add(_tap_ex(6))
    assert tr.ready()


def test_trainer_window_dedup_keeps_latest():
    cfg = LNNConfig(num_gnn_layers=2, hidden_dim=4, feat_dim=6)
    tr = RollingWindowTrainer(cfg, WindowPolicy(min_window=1, max_window=8, stride=1))
    tr.add(_tap_ex(0, order_id=42, seq=1, label=0.0))
    tr.add(_tap_ex(1, order_id=7, seq=2))
    tr.add(_tap_ex(2, order_id=42, seq=3, label=1.0))   # label-log correction
    window = tr._window()
    assert len(window) == 2
    by_id = {e.order_id: e for e in window}
    assert by_id[42].label == 1.0 and by_id[42].seq == 3
    # live traffic (order_id == -1) is keyed by seq: never collapsed
    tr2 = RollingWindowTrainer(cfg, WindowPolicy(min_window=1, max_window=8, stride=1))
    tr2.add(_tap_ex(0, order_id=-1, seq=1))
    tr2.add(_tap_ex(1, order_id=-1, seq=2))
    assert len(tr2._window()) == 2


def test_trainer_finetunes_and_fits_hybrid_head():
    cfg = LNNConfig(num_gnn_layers=2, hidden_dim=4, feat_dim=6, mlp_dims=(4,))
    params = lnn_init(jax.random.PRNGKey(0), cfg)
    examples = [_tap_ex(i, label=float(i % 2), snapshot=i // 4)
                for i in range(12)]
    tr = RollingWindowTrainer(cfg, WindowPolicy(min_window=8, max_window=16, stride=8),
                              optimizer="adam", lr=5e-2, steps=6, head="mlp")
    tr.extend(examples)
    res = tr.train(params)
    assert res.window == 12 and len(res.losses) == 6
    assert all(np.isfinite(l) for l in res.losses)
    assert res.losses[-1] < res.losses[0]         # it actually descends
    assert res.model is res.params                # mlp head serves the pytree

    hy = RollingWindowTrainer(cfg, WindowPolicy(min_window=8, max_window=16, stride=8),
                              steps=2, head="hybrid", gbdt_trees=5, k_max=4)
    hy.extend(examples)
    hres = hy.train(params)
    assert isinstance(hres.model, HybridModel)
    assert hres.model.lnn_params is hres.params
    with pytest.raises(ValueError, match="empty window"):
        RollingWindowTrainer(cfg).train(params)


def test_recall_at_budget_skips_nan_labels():
    labels = [1.0, 0.0, float("nan"), 1.0, 0.0, 0.0]
    scores = [0.9, 0.1, 0.99, 0.8, 0.2, 0.3]
    # top-50% of the 5 labeled rows (k=2, stable) = scores 0.9, 0.8 -> both
    # positives captured
    assert recall_at_budget(labels, scores, 0.5) == 1.0
    assert np.isnan(recall_at_budget([0.0, 0.0], [0.5, 0.5], 0.5))
    assert np.isnan(recall_at_budget([], [], 0.5))


def test_learn_section_from_dict_roundtrip():
    sc = ServiceConfig.from_dict({
        "mode": "streaming",
        "model": {"num_gnn_layers": 1, "hidden_dim": 4, "feat_dim": 2},
        "learn": {"enabled": True, "min_window": 16, "stride": 8,
                  "head": "hybrid", "promote_margin": 0.05},
    })
    assert sc.learn.enabled and sc.learn.min_window == 16
    assert sc.learn.head == "hybrid"
    back = ServiceConfig.from_dict(sc.to_dict())
    assert back.learn == sc.learn


# ------------------------------------------- scheduled checkpoint + retention
@pytest.fixture(scope="module")
def learn_world():
    events, g, _ = generate_event_stream(
        SynthConfig(num_users=30, num_rings=2, feature_noise=0.8, seed=5),
        rate_per_s=500.0)
    cfg = LNNConfig(num_gnn_layers=2, hidden_dim=8,
                    feat_dim=g.order_features.shape[1], mlp_dims=(8,))
    params = lnn_init(jax.random.PRNGKey(0), cfg)
    return events[:24], cfg, params


def _build(cfg, params):
    sc = ServiceConfig(
        mode="streaming", model=ModelSection.from_lnn_config(cfg),
    ).replace(engine={"num_workers": 1, "max_batch": 4})
    return FraudService(sc, params=params).build()


def test_auto_checkpoint_lifecycle_rules(learn_world, tmp_path):
    _events, cfg, params = learn_world
    svc = _build(cfg, params)
    with pytest.raises(ServiceLifecycleError, match="requires enable_wal"):
        svc.enable_auto_checkpoint(every_s=1.0)
    svc.enable_wal(str(tmp_path / "wal"))
    with pytest.raises(ServiceLifecycleError, match="every_s and/or"):
        svc.enable_auto_checkpoint()
    with pytest.raises(ValueError, match="every_s"):
        svc.enable_auto_checkpoint(every_s=0.0)
    with pytest.raises(ValueError, match="every_windows"):
        svc.enable_auto_checkpoint(every_windows=0)
    with pytest.raises(ValueError, match="keep_last"):
        svc.enable_auto_checkpoint(every_s=1.0, keep_last=0)
    svc.close()


def test_auto_checkpoint_fires_on_injected_clock(learn_world, tmp_path):
    events, cfg, params = learn_world
    root = str(tmp_path / "wal")
    svc = _build(cfg, params).enable_wal(root)
    t = {"now": 0.0}
    svc.enable_auto_checkpoint(every_s=10.0, keep_last=2,
                               clock=lambda: t["now"])
    for ev in events[:4]:
        svc.submit(ev)
    assert svc.stats().extra["auto_checkpoint"]["checkpoints"] == 0
    t["now"] = 11.0                       # cadence due on the next apply
    svc.submit(events[4])
    st = svc.stats().extra["auto_checkpoint"]
    assert st["checkpoints"] == 1
    assert len(list_checkpoints(root)) == 1
    # each subsequent period adds one, retention keeps the newest 2
    for i, ev in enumerate(events[5:9]):
        t["now"] += 11.0
        svc.submit(ev)
    st = svc.stats().extra["auto_checkpoint"]
    assert st["checkpoints"] == 5
    assert len(list_checkpoints(root)) == 2
    assert st["pruned"] == 3
    svc.close()


def test_prune_checkpoints_keeps_newest(learn_world, tmp_path):
    events, cfg, params = learn_world
    root = str(tmp_path / "wal")
    svc = _build(cfg, params).enable_wal(root)
    for i, ev in enumerate(events[:6]):
        svc.submit(ev)
        if i % 2 == 1:
            svc.checkpoint()
    found = list_checkpoints(root)
    assert len(found) == 3
    removed = prune_checkpoints(root, keep_last=2)
    assert removed == found[:1]
    assert list_checkpoints(root) == found[1:]
    assert prune_checkpoints(root, keep_last=2) == []
    with pytest.raises(ValueError, match="keep_last"):
        prune_checkpoints(root, keep_last=0)
    svc.close()


# -------------------------------------------------------- shared rollback path
def test_rollback_model_restores_last_good(learn_world):
    _events, cfg, params = learn_world
    svc = _build(cfg, params)
    with pytest.raises(ServiceLifecycleError, match="last-good"):
        svc.rollback_model()              # no swap has happened yet
    v1 = svc.register_perturbed(0, scale=0.0, version=1)
    svc.activate_model(v1)
    assert svc.last_good_version == 0
    svc.enable_shadow(0, fraction=1.0)    # rollback also kills the alert src
    restored = svc.rollback_model("test reason")
    assert restored == 0 and svc.model_version == 0
    assert svc.shadow_stats() == {}
    st = svc.stats()
    assert st.rollbacks == 1 and st.last_good_version is None
    assert svc.last_rollback == {"from": v1, "to": 0, "reason": "test reason"}
    with pytest.raises(ServiceLifecycleError):
        svc.rollback_model()              # consumed: no ping-pong
    svc.close()


def test_register_perturbed_keeps_hybrid_structure(learn_world):
    """Perturbing a hybrid version must stay a HybridModel (tree_map over
    the dataclass would collapse it into a 0-d object array and crash the
    speed layer's non-hybrid scoring branch)."""
    _events, cfg, params = learn_world
    svc = _build(cfg, params)
    rng = np.random.default_rng(0)
    emb_dim = cfg.hidden_dim + cfg.feat_dim
    from repro.baselines.gbdt import GBDTConfig
    from repro.models.hybrid import train_hybrid

    hy = train_hybrid(params, cfg,
                      rng.normal(0, 1, (32, emb_dim)).astype(np.float32),
                      (rng.random(32) > 0.7).astype(np.float32),
                      gbdt_cfg=GBDTConfig(num_trees=3))
    vh = svc.register_model(hy)
    vp = svc.register_perturbed(vh, scale=2.0)
    perturbed = svc.model_params(vp)
    assert isinstance(perturbed, HybridModel)
    assert perturbed.gbdt is hy.gbdt      # head shared by reference
    a = np.asarray(jax.tree_util.tree_leaves(hy.lnn_params)[0])
    b = np.asarray(jax.tree_util.tree_leaves(perturbed.lnn_params)[0])
    assert not np.allclose(a, b)
    svc.close()


# ------------------------------------------------------------ gateway surface
def _post(url, body):
    req = urllib.request.Request(url, data=json.dumps(body).encode(),
                                 headers={"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req, timeout=30) as r:
            return r.status, json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def _get(url):
    try:
        with urllib.request.urlopen(url, timeout=30) as r:
            return r.status, r.read().decode()
    except urllib.error.HTTPError as e:
        return e.code, e.read().decode()


def test_gateway_learn_endpoints(learn_world, tmp_path):
    from repro.gateway import serve_gateway

    events, cfg, params = learn_world
    sc = ServiceConfig(
        mode="streaming", model=ModelSection.from_lnn_config(cfg),
    ).replace(engine={"num_workers": 1, "max_batch": 4},
              gateway={"checkpoint_dir": str(tmp_path / "wal")},
              learn={"enabled": True, "min_window": 4, "stride": 4,
                     "steps": 1, "min_eval": 2, "min_eval_pos": 1,
                     "eval_max": 8})
    gw = serve_gateway(sc, params)
    try:
        for ev in events[:6]:
            _post(gw.url + "/v1/score", {"event": {
                "order_id": ev.order_id, "snapshot": ev.snapshot,
                "entities": list(ev.entities),
                "features": ev.features.tolist(), "label": float(ev.label),
                "arrival": ev.arrival}})
        code, out = _post(gw.url + "/admin/train", {"force": True})
        assert code == 200
        assert out["trained"] is not None and out["examples"] >= 1
        assert out["state"] == "shadowing"
        code, body = _get(gw.url + "/v1/learn/stats")
        stats = json.loads(body)
        assert code == 200 and stats["state"] == "shadowing"
        assert stats["trainer"]["fires"] == 1
        _code, metrics = _get(gw.url + "/metrics")
        assert 'repro_learn_info{state="shadowing"} 1' in metrics
        assert "repro_learn_fires_total 1" in metrics
        assert "repro_service_rollbacks_total 0" in metrics
    finally:
        gw.close()


def test_gateway_learn_endpoints_409_without_learner(learn_world):
    from repro.gateway import FraudGateway

    _events, cfg, params = learn_world
    svc = _build(cfg, params)
    gw = FraudGateway(svc).start()
    try:
        code, out = _post(gw.url + "/admin/train", {})
        assert code == 409 and "learn.enabled" in out["error"]
        code, body = _get(gw.url + "/v1/learn/stats")
        assert code == 409
    finally:
        gw.close()


def test_gateway_auto_rollback_ignores_candidate_shadows(learn_world):
    """gateway.auto_rollback fires only for 'canary'-role shadows: a learn
    candidate is EXPECTED to diverge, so its alert must not roll back."""
    from repro.gateway import FraudGateway

    events, cfg, params = learn_world
    sc = ServiceConfig(
        mode="streaming", model=ModelSection.from_lnn_config(cfg),
    ).replace(engine={"num_workers": 1, "max_batch": 4},
              gateway={"auto_rollback": True})
    svc = FraudService(sc, params=params).build()
    v1 = svc.register_perturbed(0, scale=0.0, version=1)
    svc.activate_model(v1)                # last_good = 0, armed
    vc = svc.register_perturbed(v1, scale=5.0)
    svc.enable_shadow(vc, fraction=1.0, threshold=1e-6, collect_eval=8,
                      role="candidate")
    gw = FraudGateway(svc, config=sc.gateway).start()
    try:
        for ev in events[:8]:
            _post(gw.url + "/v1/score", {"event": {
                "order_id": 50_000 + ev.order_id, "snapshot": ev.snapshot,
                "entities": list(ev.entities),
                "features": ev.features.tolist(), "arrival": ev.arrival}})
        _post(gw.url + "/admin/drain", {})
        assert svc.shadow_stats().get("alert_active") is True
        assert svc.stats().rollbacks == 0          # candidate: no rollback
        assert svc.model_version == v1
    finally:
        gw.close()


def test_gateway_auto_rollback_on_canary_alert(learn_world):
    from repro.gateway import FraudGateway

    events, cfg, params = learn_world
    sc = ServiceConfig(
        mode="streaming", model=ModelSection.from_lnn_config(cfg),
    ).replace(engine={"num_workers": 1, "max_batch": 4},
              gateway={"auto_rollback": True})
    svc = FraudService(sc, params=params).build()
    bad = svc.register_perturbed(0, scale=5.0)
    svc.activate_model(bad)               # last_good = 0
    svc.enable_shadow(0, fraction=1.0, threshold=1e-6)   # role defaults canary
    gw = FraudGateway(svc, config=sc.gateway).start()
    try:
        for ev in events[:8]:
            _post(gw.url + "/v1/score", {"event": {
                "order_id": 60_000 + ev.order_id, "snapshot": ev.snapshot,
                "entities": list(ev.entities),
                "features": ev.features.tolist(), "arrival": ev.arrival}})
        _post(gw.url + "/admin/drain", {})
        assert svc.model_version == 0              # rolled back to last-good
        assert svc.stats().rollbacks == 1
        assert "auto-rollback" in svc.last_rollback["reason"]
        _code, metrics = _get(gw.url + "/metrics")
        assert "repro_service_rollbacks_total 1" in metrics
    finally:
        gw.close()
