"""Property tests for the MoE routing invariants (hypothesis)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import init_params
from repro.models.moe import moe_apply, moe_apply_dense_ref


def _moe_params_and_cfg(seed=0):
    cfg = get_config("phi3_5_moe").reduced()
    params = init_params(jax.random.PRNGKey(seed), cfg)
    moe_p = jax.tree_util.tree_map(lambda x: x[0], params["groups"]["decoder"]["moe"])
    return moe_p, cfg


@settings(max_examples=10, deadline=None)
@given(t=st.sampled_from([8, 16, 32, 64]), seed=st.integers(0, 100))
def test_full_capacity_matches_dense_oracle(t, seed):
    moe_p, cfg = _moe_params_and_cfg()
    x = jnp.asarray(np.random.default_rng(seed).normal(size=(t, cfg.d_model)),
                    jnp.float32)
    y, aux = moe_apply(moe_p, cfg, x, full_capacity=True)
    y_ref = moe_apply_dense_ref(moe_p, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-3, rtol=2e-3)
    # Switch aux loss concentrates near 1 under near-uniform routing; finite
    # samples wobble a few percent either side
    assert 0.5 < float(aux) < 4.0


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 100), cf=st.sampled_from([0.5, 1.0]))
def test_capacity_drops_shrink_not_explode(seed, cf):
    """With tight capacity, dropped tokens lose gate mass — the output must
    be a 'partial' version of the full-capacity output, never larger in a
    way that indicates double-counted slots."""
    moe_p, cfg0 = _moe_params_and_cfg()
    cfg = dataclasses.replace(cfg0, moe_capacity_factor=cf)
    x = jnp.asarray(np.random.default_rng(seed).normal(size=(64, cfg.d_model)),
                    jnp.float32)
    y_full, _ = moe_apply(moe_p, cfg, x, full_capacity=True)
    y_cap, _ = moe_apply(moe_p, cfg, x, full_capacity=False)
    # no NaNs, and capped norm should not exceed full norm by more than noise
    assert np.isfinite(np.asarray(y_cap)).all()
    n_full = float(jnp.linalg.norm(y_full))
    n_cap = float(jnp.linalg.norm(y_cap))
    assert n_cap <= n_full * 1.05


def test_deterministic_routing():
    moe_p, cfg = _moe_params_and_cfg()
    x = jnp.asarray(np.random.default_rng(0).normal(size=(32, cfg.d_model)),
                    jnp.float32)
    y1, a1 = moe_apply(moe_p, cfg, x, full_capacity=True)
    y2, a2 = moe_apply(moe_p, cfg, x, full_capacity=True)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


def test_serve_mode_resolution():
    """serve_auto must pick TP-only for small models and FSDP for llama-90b,
    resolved against the FULL depth (the 1-layer-variant bug regression)."""
    # use the resolver logic directly with a fake 16-way mesh
    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    from repro.dist.sharding import _fits_tp_only
    from repro.launch.steps import abstract_params

    mesh = FakeMesh()
    small = abstract_params(get_config("granite-3-2b").with_padding(16))
    big = abstract_params(get_config("llama-3.2-vision-90b").with_padding(16))
    assert _fits_tp_only(mesh, small) is True
    assert _fits_tp_only(mesh, big) is False
