"""Drifting named-attack workload — the continuous-learning stressor.

``benchmarks/learning_bench.py`` needs a stream whose fraud *changes
shape mid-stream*: a model trained on the first phase must measurably
lose ring recall on the second, and a fine-tune on tapped second-phase
data must recover it.  :func:`drifting_attack_stream` builds that from
two :func:`~repro.data.attacks.generate_attack_stream` phases:

* **Phase A** is the base workload unchanged.
* **Phase B** re-generates with a different seed and a *shifted ring
  signature*: ring orders drop the generic fraud-feature recipe phase A's
  model keyed on and instead carry a fresh, localized signature (an
  offset on two previously-uninformative feature dims), while the ring
  *linkage* gets weaker (wider device/payment pool).  Every phase-B
  entity id is re-tagged into a disjoint id range, so phase-B rings share
  no devices or payment tokens with phase A — the old model's graph
  evidence does not transfer.

Phase B's snapshots and arrivals continue phase A's clock, so the
combined list replays as ONE event-time-ordered stream through the
serving path.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.hetero import strip_type, tag_entity, type_code_of
from repro.data.attacks import AttackConfig, generate_attack_stream
from repro.stream.events import CheckoutEvent

__all__ = ["drifting_attack_stream"]

#: phase-B ring signature: z-score offset added on these raw-feature dims
_DRIFT_DIMS = (4, 5)
_DRIFT_SHIFT = 2.5
#: phase-B order ids live above this floor — disjoint from any phase A id
_ORDER_OFFSET = 1_000_000


def drifting_attack_stream(cfg: AttackConfig, *, drift_seed: int | None = None,
                           rate_per_s: float = 200.0):
    """Two-phase drifting stream.

    Returns ``(events, patterns, split)``: one event-time-ordered list
    covering both phases, the per-event pattern names, and ``split`` — the
    index of the first phase-B event.  Deterministic in ``cfg.seed`` /
    ``drift_seed`` (default ``cfg.seed + 1``).
    """
    ev_a, pat_a = generate_attack_stream(cfg, rate_per_s=rate_per_s)

    b_cfg = dataclasses.replace(
        cfg,
        seed=cfg.seed + 1 if drift_seed is None else int(drift_seed),
        ring_pool=max(2 * cfg.ring_pool, cfg.ring_pool + 2),
    )
    ev_b, pat_b = generate_attack_stream(b_cfg, rate_per_s=rate_per_s)

    # disjoint id space: strip the type tag, offset past phase A's raw ids,
    # re-tag — phase-B entities share nothing with phase A
    offset = 1 + max(
        (strip_type(e) for ev in ev_a for e in ev.entities), default=0)
    rng = np.random.default_rng(b_cfg.seed + 7)
    t_shift = cfg.num_snapshots
    t_last = ev_a[-1].arrival if ev_a else 0.0
    shifted = []
    for ev, pat in zip(ev_b, pat_b):
        ents = tuple(
            tag_entity(strip_type(e) + offset, type_code_of(e))
            for e in ev.entities)
        feats = np.array(ev.features, np.float32)
        if pat == "ring":
            # the drift: legit-like body + a NEW signature on dims the
            # phase-A model never learned to read
            feats[:] = rng.normal(0.0, 1.0, len(feats))
            for d in _DRIFT_DIMS:
                feats[d] += _DRIFT_SHIFT
        shifted.append(CheckoutEvent(
            order_id=int(ev.order_id) + _ORDER_OFFSET,
            snapshot=int(ev.snapshot) + t_shift,
            entities=ents, features=feats, label=ev.label,
            arrival=float(ev.arrival) + t_last))
    events = list(ev_a) + shifted
    patterns = np.concatenate([pat_a, pat_b])
    return events, patterns, len(ev_a)
