"""Shadow-gated promotion with automatic rollback.

The controller closes the deployment half of the loop: every fine-tune
candidate is registered and shadow-scored on **live traffic** (the PR-6
canary machinery, now collecting ``[label, primary, shadow]`` eval
triples), and only promoted when its recall@budget beats the incumbent's
by a configured margin on the same sampled responses — a paired
comparison, so traffic mix cancels out.

State machine (see ``docs/learning.md`` for the diagram)::

    idle --submit_candidate--> shadowing --beats incumbent--> watching
      ^                            |  (margin not met /            |
      |                            |   eval budget exhausted)      |
      +------- reject -------------+                               |
      ^                                                            |
      +-- rollback (divergence alert | recall regression) ---------+
      +-- cleared (watch window healthy) --------------------------+

After a promotion the controller keeps shadow-scoring the *displaced
incumbent* (``role='last_good'``): a sticky divergence alert or a recall
regression beyond ``rollback_margin`` triggers
:meth:`FraudService.rollback_model` — the same shared rollback path the
gateway's auto-rollback uses.  All eval state lives in the service's
shadow dict, which rides checkpoints: a crash mid-eval resumes the
window on restore (:meth:`PromotionController.attach`) instead of
double-counting.
"""
from __future__ import annotations

import math

import numpy as np

__all__ = ["PromotionController", "recall_at_budget"]


def recall_at_budget(labels, scores, budget: float) -> float:
    """Recall among the top-``budget`` fraction by score (the paper's
    review-budget metric).  NaN labels are skipped; returns NaN when no
    labeled positives remain."""
    labels = np.asarray(labels, np.float64)
    scores = np.asarray(scores, np.float64)
    keep = ~np.isnan(labels)
    labels, scores = labels[keep], scores[keep]
    pos = float((labels > 0.5).sum())
    if labels.size == 0 or pos == 0:
        return float("nan")
    k = max(1, int(round(budget * labels.size)))
    top = np.argsort(-scores, kind="stable")[:k]
    return float((labels[top] > 0.5).sum() / pos)


class PromotionController:
    """Drives candidate versions through shadow eval → promote → watch.

    All thresholds mirror :class:`~repro.service.config.LearnSection`;
    the controller itself is stateless beyond its phase tag — the eval
    evidence lives in the service's checkpointed shadow dict, so
    :meth:`attach` can rebuild a controller mid-flight after a restore.
    """

    def __init__(self, service, *, promote_margin: float = 0.02,
                 min_eval: int = 32, min_eval_pos: int = 3,
                 eval_budget: float = 0.15, eval_max: int = 4096,
                 shadow_fraction: float = 1.0,
                 rollback_margin: float = 0.05, watch_min_eval: int = 32,
                 watch_divergence_threshold: float = 5.0):
        self.service = service
        self.promote_margin = float(promote_margin)
        self.min_eval, self.min_eval_pos = int(min_eval), int(min_eval_pos)
        self.eval_budget, self.eval_max = float(eval_budget), int(eval_max)
        self.shadow_fraction = float(shadow_fraction)
        self.rollback_margin = float(rollback_margin)
        self.watch_min_eval = int(watch_min_eval)
        self.watch_divergence_threshold = float(watch_divergence_threshold)
        self.state = "idle"          # 'idle' | 'shadowing' | 'watching'
        self.candidate_version: int | None = None
        self.stats = {"submitted": 0, "promoted": 0, "rejected": 0,
                      "rollbacks": 0, "cleared": 0}
        self.last_decision: dict | None = None

    # ---------------------------------------------------------------- attach
    @classmethod
    def attach(cls, service, **kwargs) -> "PromotionController":
        """Rebuild a controller from a (possibly restored) service: the
        shadow dict's ``role`` tag says which phase was in flight, and its
        checkpointed eval buffer resumes the window without double-counting
        (``tests/test_learn_promotion.py``)."""
        ctl = cls(service, **kwargs)
        sh = service.shadow_stats()
        role = sh.get("role")
        if role == "candidate":
            ctl.state = "shadowing"
            ctl.candidate_version = int(sh["version"])
        elif role == "last_good":
            ctl.state = "watching"
            ctl.candidate_version = int(service.model_version)
        return ctl

    # ---------------------------------------------------------------- submit
    def submit_candidate(self, model, version: int | None = None) -> int:
        """Register ``model`` (an LNN pytree or a HybridModel) and start
        shadow-scoring it on live traffic.  One candidate at a time — a
        submission while not idle raises."""
        if self.state != "idle":
            raise RuntimeError(
                f"submit_candidate() while {self.state!r} — one candidate "
                "at a time; wait for promote/reject/rollback")
        v = self.service.register_model(model, version)
        self.service.enable_shadow(
            v, fraction=self.shadow_fraction, collect_eval=self.eval_max,
            role="candidate")
        self.candidate_version = v
        self.state = "shadowing"
        self.stats["submitted"] += 1
        return v

    # ------------------------------------------------------------------ step
    def _recalls(self, sh: dict) -> tuple[float, float, int, int]:
        """(primary_recall, shadow_recall, n_labeled, n_pos) from the
        eval triples."""
        ev = np.asarray(sh.get("eval", ()), np.float64).reshape(-1, 3)
        labels = ev[:, 0]
        keep = ~np.isnan(labels)
        n = int(keep.sum())
        pos = int((labels[keep] > 0.5).sum())
        return (recall_at_budget(labels, ev[:, 1], self.eval_budget),
                recall_at_budget(labels, ev[:, 2], self.eval_budget),
                n, pos)

    def step(self) -> dict | None:
        """Advance the state machine one tick; returns the decision made
        this tick (promote/reject/rollback/cleared) or None."""
        if self.state == "shadowing":
            return self._step_shadowing()
        if self.state == "watching":
            return self._step_watching()
        return None

    def _step_shadowing(self) -> dict | None:
        svc = self.service
        sh = svc.shadow_stats()
        if sh.get("role") != "candidate":   # shadow stolen out from under us
            self.state, self.candidate_version = "idle", None
            return None
        inc_recall, cand_recall, n, pos = self._recalls(sh)
        exhausted = len(sh.get("eval", ())) >= sh.get("eval_max", self.eval_max)
        if n < self.min_eval or pos < self.min_eval_pos:
            if not exhausted:
                return None            # keep collecting evidence
        beats = (not math.isnan(cand_recall) and not math.isnan(inc_recall)
                 and cand_recall >= inc_recall + self.promote_margin)
        decision = {
            "phase": "shadowing", "candidate": self.candidate_version,
            "incumbent": svc.model_version, "n_eval": n, "n_pos": pos,
            "incumbent_recall": inc_recall, "candidate_recall": cand_recall,
            "margin": self.promote_margin,
        }
        if beats:
            svc.activate_model(self.candidate_version)
            # keep watching: the displaced incumbent shadows the promotee
            last_good = svc.last_good_version
            if last_good is not None:
                svc.enable_shadow(
                    last_good, fraction=self.shadow_fraction,
                    threshold=self.watch_divergence_threshold,
                    collect_eval=self.eval_max, role="last_good")
                self.state = "watching"
            else:
                svc.disable_shadow()
                self.state, self.candidate_version = "idle", None
            self.stats["promoted"] += 1
            decision["action"] = "promote"
        elif exhausted or (n >= self.min_eval and pos >= self.min_eval_pos):
            svc.disable_shadow()
            self.state, self.candidate_version = "idle", None
            self.stats["rejected"] += 1
            decision["action"] = "reject"
        else:
            return None
        self.last_decision = decision
        return decision

    def _step_watching(self) -> dict | None:
        svc = self.service
        sh = svc.shadow_stats()
        if sh.get("role") != "last_good":
            self.state, self.candidate_version = "idle", None
            return None
        decision = {"phase": "watching", "promoted": svc.model_version}
        if sh.get("alert_active"):
            decision.update(action="rollback", reason="shadow divergence "
                            f"alert (max={sh['divergence_max']:.4g})")
            decision["restored"] = svc.rollback_model(decision["reason"])
        else:
            cand_recall, good_recall, n, pos = self._recalls(sh)
            decision.update(n_eval=n, n_pos=pos,
                            promoted_recall=cand_recall,
                            last_good_recall=good_recall)
            if (n >= self.watch_min_eval and pos >= self.min_eval_pos
                    and not math.isnan(cand_recall)
                    and not math.isnan(good_recall)
                    and cand_recall < good_recall - self.rollback_margin):
                decision.update(action="rollback", reason="recall regression "
                                f"({cand_recall:.3f} < {good_recall:.3f} - "
                                f"{self.rollback_margin})")
                decision["restored"] = svc.rollback_model(decision["reason"])
            elif len(sh.get("eval", ())) >= sh.get("eval_max", self.eval_max):
                svc.disable_shadow()   # watch window closed, promotee healthy
                decision["action"] = "cleared"
            else:
                return None
        if decision["action"] == "rollback":
            self.stats["rollbacks"] += 1
        else:
            self.stats["cleared"] += 1
        self.state, self.candidate_version = "idle", None
        self.last_decision = decision
        return decision
