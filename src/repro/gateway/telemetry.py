"""Prometheus-style telemetry primitives — ``repro.gateway.telemetry``.

A dependency-free miniature of the Prometheus client: :class:`Counter`,
:class:`Gauge`, and :class:`Histogram` registered in a
:class:`MetricsRegistry` that renders the text exposition format
(``# HELP`` / ``# TYPE`` headers, ``name{label="v"} value`` samples) for
the gateway's ``GET /metrics`` endpoint.

Design points:

* every mutation is guarded by one registry-wide lock, so concurrent
  request-handler threads never tear a histogram (bucket counts, sum and
  count always move together);
* labeled children are created on first touch — scrapes only show series
  that actually happened (Prometheus convention);
* :meth:`MetricsRegistry.snapshot` returns the same data as a JSON-safe
  dict, so ``/v1/stats`` and ``/metrics`` render from one source of truth.

Label values are escaped per the exposition spec (backslash, quote,
newline).  Histogram buckets follow the cumulative ``le`` convention with
a terminal ``+Inf`` bucket.
"""
from __future__ import annotations

import math
import threading


def _fmt_value(v: float) -> str:
    """Prometheus sample value: integers render bare, floats repr-style."""
    if isinstance(v, bool):
        return str(int(v))
    if isinstance(v, int) or (isinstance(v, float) and v.is_integer()
                              and abs(v) < 1e15):
        return str(int(v))
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    return repr(float(v))


def _escape(value: str) -> str:
    return (str(value).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _render_labels(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_escape(v)}"' for k, v in labels.items())
    return "{" + inner + "}"


class _Metric:
    """Base: a named family of labeled children."""

    kind = "untyped"

    def __init__(self, name: str, help_text: str, labelnames: tuple = ()):
        self.name = name
        self.help_text = help_text
        self.labelnames = tuple(labelnames)
        self._children: dict[tuple, object] = {}
        self._lock = threading.Lock()   # replaced by the registry's lock

    def _key(self, labels: dict) -> tuple:
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: expected labels {self.labelnames}, "
                f"got {tuple(sorted(labels))}")
        return tuple(str(labels[k]) for k in self.labelnames)

    def _label_dict(self, key: tuple) -> dict:
        return dict(zip(self.labelnames, key))


class Counter(_Metric):
    """Monotonically increasing count (``_total`` naming convention)."""

    kind = "counter"

    def inc(self, amount: float = 1, **labels) -> None:
        if amount < 0:
            raise ValueError(f"{self.name}: counters only go up")
        key = self._key(labels)
        with self._lock:
            self._children[key] = self._children.get(key, 0) + amount

    def value(self, **labels) -> float:
        with self._lock:
            return self._children.get(self._key(labels), 0)

    def render(self) -> list[str]:
        with self._lock:
            items = sorted(self._children.items())
        return [f"{self.name}{_render_labels(self._label_dict(k))} "
                f"{_fmt_value(v)}" for k, v in items]

    def snapshot(self) -> dict:
        with self._lock:
            return {",".join(k) if k else "": v
                    for k, v in sorted(self._children.items())}


class Gauge(_Metric):
    """A value that can go anywhere (queue depth, alert flag, ...)."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        key = self._key(labels)
        with self._lock:
            self._children[key] = value

    def inc(self, amount: float = 1, **labels) -> None:
        key = self._key(labels)
        with self._lock:
            self._children[key] = self._children.get(key, 0) + amount

    def value(self, **labels) -> float:
        with self._lock:
            return self._children.get(self._key(labels), 0)

    render = Counter.render
    snapshot = Counter.snapshot


class Histogram(_Metric):
    """Cumulative-bucket latency histogram (Prometheus ``le`` convention).

    ``observe(v)`` increments every bucket whose upper bound is >= v, the
    ``+Inf`` bucket, ``_sum`` and ``_count`` — atomically under the lock.
    """

    kind = "histogram"

    def __init__(self, name: str, help_text: str, buckets: tuple,
                 labelnames: tuple = ()):
        super().__init__(name, help_text, labelnames)
        bs = tuple(float(b) for b in buckets)
        if list(bs) != sorted(set(bs)):
            raise ValueError(f"{name}: buckets must be strictly increasing")
        self.buckets = bs

    def _child(self, key: tuple) -> dict:
        c = self._children.get(key)
        if c is None:
            c = self._children[key] = {
                "buckets": [0] * (len(self.buckets) + 1),  # +1 = +Inf
                "sum": 0.0, "count": 0,
            }
        return c

    def observe(self, value: float, **labels) -> None:
        key = self._key(labels)
        with self._lock:
            c = self._child(key)
            for i, ub in enumerate(self.buckets):
                if value <= ub:
                    c["buckets"][i] += 1
            c["buckets"][-1] += 1
            c["sum"] += value
            c["count"] += 1

    def count(self, **labels) -> int:
        with self._lock:
            c = self._children.get(self._key(labels))
            return 0 if c is None else c["count"]

    def render(self) -> list[str]:
        lines: list[str] = []
        with self._lock:
            items = sorted((k, {"buckets": list(c["buckets"]),
                                "sum": c["sum"], "count": c["count"]})
                           for k, c in self._children.items())
        for key, c in items:
            base = self._label_dict(key)
            cum = 0
            for i, ub in enumerate(self.buckets):
                cum = c["buckets"][i]
                lines.append(
                    f"{self.name}_bucket"
                    f"{_render_labels({**base, 'le': _fmt_value(ub)})} {cum}")
            lines.append(
                f"{self.name}_bucket{_render_labels({**base, 'le': '+Inf'})} "
                f"{c['buckets'][-1]}")
            lines.append(f"{self.name}_sum{_render_labels(base)} "
                         f"{_fmt_value(c['sum'])}")
            lines.append(f"{self.name}_count{_render_labels(base)} "
                         f"{c['count']}")
        return lines

    def snapshot(self) -> dict:
        with self._lock:
            return {
                ",".join(k) if k else "": {
                    "count": c["count"], "sum": c["sum"],
                    "buckets": dict(zip(
                        [_fmt_value(b) for b in self.buckets] + ["+Inf"],
                        c["buckets"])),
                }
                for k, c in sorted(self._children.items())
            }


class MetricsRegistry:
    """All of a gateway's metric families, in registration order.

    One lock is shared by every registered metric: a scrape racing a
    request thread sees each family internally consistent (a histogram's
    ``_count`` never runs ahead of its ``+Inf`` bucket).
    """

    def __init__(self):
        self._metrics: list[_Metric] = []
        self._by_name: dict[str, _Metric] = {}
        self._lock = threading.RLock()

    def register(self, metric: _Metric):
        if metric.name in self._by_name:
            raise ValueError(f"metric {metric.name!r} already registered")
        metric._lock = self._lock   # one shared lock, scrape-consistent
        self._metrics.append(metric)
        self._by_name[metric.name] = metric
        return metric

    def counter(self, name: str, help_text: str, labelnames: tuple = ()) -> Counter:
        return self.register(Counter(name, help_text, labelnames))

    def gauge(self, name: str, help_text: str, labelnames: tuple = ()) -> Gauge:
        return self.register(Gauge(name, help_text, labelnames))

    def histogram(self, name: str, help_text: str, buckets: tuple,
                  labelnames: tuple = ()) -> Histogram:
        return self.register(Histogram(name, help_text, buckets, labelnames))

    def __getitem__(self, name: str) -> _Metric:
        return self._by_name[name]

    def render(self) -> str:
        """The Prometheus text exposition body (trailing newline included)."""
        lines: list[str] = []
        for m in self._metrics:
            samples = m.render()
            if not samples:
                continue
            lines.append(f"# HELP {m.name} {m.help_text}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            lines.extend(samples)
        return "\n".join(lines) + "\n"

    def snapshot(self) -> dict:
        """JSON-safe mirror of every family (``/v1/stats`` gateway block)."""
        return {m.name: m.snapshot() for m in self._metrics}


__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]
