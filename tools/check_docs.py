"""Docs honesty checker (the CI ``docs`` job).

Three guarantees:

1. every intra-repo markdown link ``[text](target)`` in README.md +
   docs/*.md resolves to a real file or directory (anchors and external
   http(s)/mailto links skipped);
2. every inline code reference to a repo path — ``src/repro/...``,
   ``tests/...``, ``benchmarks/...``, ``examples/...``, ``docs/...``,
   ``tools/...`` — points at an existing file, so renames can't silently
   rot the docs.  ``path::test_name`` pytest selectors are handled (the
   regex stops at the extension);
3. every public symbol in the reviewed API surface
   (``tools/api_surface.json``) carries a real docstring — the snapshot
   gate already forces surface changes through review, this forces them
   through *documentation*.  A dataclass's auto-generated
   ``Name(field: type, ...)`` signature string does not count.

Exit code 1 with a per-file / per-symbol report when anything is broken.

Run:  python tools/check_docs.py
"""
from __future__ import annotations

import importlib
import inspect
import json
import os
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
PATH_RE = re.compile(
    r"\b((?:src/repro|tests|benchmarks|examples|docs|tools)"
    r"/[\w\-./]*\.(?:py|md|yml|json))\b"
)
EXTERNAL = ("http://", "https://", "mailto:", "#")


def md_files() -> list[Path]:
    files = [ROOT / "README.md"]
    files += sorted((ROOT / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def check_file(md: Path) -> list[str]:
    errors = []
    text = md.read_text(encoding="utf-8")
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(EXTERNAL):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        # leading "/" means repo-root-relative (GitHub-style), not fs-absolute
        resolved = (ROOT / path.lstrip("/")) if path.startswith("/") else (md.parent / path)
        if not resolved.exists():
            errors.append(f"broken link -> {target}")
    for m in PATH_RE.finditer(text):
        if not (ROOT / m.group(1)).exists():
            errors.append(f"missing file reference -> {m.group(1)}")
    return sorted(set(errors))


def check_docstrings() -> tuple[list[str], int]:
    """Docstring coverage over the reviewed API surface.

    Returns ``(errors, n_symbols_checked)``.  Non-callable exports (bare
    constants like ``CHECKPOINT_FORMAT``) are exempt — there is nothing to
    call, so the module docstring is their documentation.
    """
    sys.path.insert(0, os.path.join(str(ROOT), "src"))
    surface = json.loads((ROOT / "tools" / "api_surface.json").read_text())
    errors: list[str] = []
    n = 0
    for mod_name, names in surface.items():
        mod = importlib.import_module(mod_name)
        if not inspect.getdoc(mod):
            errors.append(f"{mod_name}: module docstring missing")
        for name in names:
            obj = getattr(mod, name, None)
            if not callable(obj):
                continue
            n += 1
            doc = inspect.getdoc(obj)
            # a dataclass with no explicit docstring inherits its generated
            # signature string — that documents nothing, flag it
            if not doc or doc.startswith(f"{name}("):
                errors.append(f"{mod_name}.{name}: public symbol has no "
                              "real docstring")
    return sorted(set(errors)), n


def main() -> int:
    n_checked, failed = 0, False
    for md in md_files():
        n_checked += 1
        errors = check_file(md)
        if errors:
            failed = True
            rel = md.relative_to(ROOT)
            for e in errors:
                print(f"FAIL {rel}: {e}")
    doc_errors, n_symbols = check_docstrings()
    for e in doc_errors:
        print(f"FAIL docstrings: {e}")
    failed |= bool(doc_errors)
    if failed:
        return 1
    print(f"docs check OK ({n_checked} markdown files, "
          f"{n_symbols} documented API symbols)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
