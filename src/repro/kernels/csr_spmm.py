"""Pallas TPU kernel: weighted neighbor gather-sum (the GCN/SAGE hot loop).

    out[i, :] = sum_d weights[i, d] * h[nbr_idx[i, d], :]

TPU adaptation of the scatter/gather SpMM GPU pattern: instead of atomic
scatter-adds, the padded in-neighbor layout makes aggregation a *dense*
strip-mined loop over the fixed neighbor width D, with a sublane row-gather
per step (Mosaic supports dynamic row gathers on the second-minor dim for
32-bit types).  Grid tiles nodes x features so every block is MXU/VPU
aligned; the feature matrix ``h`` is tiled on the feature axis only — a
community's node dim (~1k) always fits VMEM.

VMEM budget per program (defaults bn=128, bh=128, D<=64, f32):
    h tile     N x bh     = 1024*128*4  = 512 KiB
    msgs       bn x bh    = 64 KiB  (per neighbor step)
    idx/w      bn x D     = 2 x 32 KiB
    out        bn x bh    = 64 KiB                      << 16 MiB VMEM
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.utils.padding import ceil_div


def _spmm_kernel(h_ref, idx_ref, w_ref, out_ref):
    h = h_ref[...]            # [N, bh] — full node dim, feature tile
    idx = idx_ref[...]        # [bn, D]
    w = w_ref[...]            # [bn, D]
    bn, D = idx.shape
    acc = jnp.zeros((bn, h.shape[1]), jnp.float32)

    def body(d, acc):
        rows = jnp.take(h, idx[:, d], axis=0)          # sublane gather [bn, bh]
        return acc + rows.astype(jnp.float32) * w[:, d][:, None].astype(jnp.float32)

    acc = jax.lax.fori_loop(0, D, body, acc)
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "block_h", "interpret"))
def csr_spmm_pallas(h, nbr_idx, weights, block_n: int = 128, block_h: int = 128,
                    interpret: bool = True):
    n, feat = h.shape
    _, d = nbr_idx.shape
    bn = min(block_n, n)
    bh = min(block_h, feat)
    grid = (ceil_div(n, bn), ceil_div(feat, bh))
    return pl.pallas_call(
        _spmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, bh), lambda i, j: (0, j)),      # h: full nodes, feat tile
            pl.BlockSpec((bn, d), lambda i, j: (i, 0)),      # idx: node tile
            pl.BlockSpec((bn, d), lambda i, j: (i, 0)),      # weights: node tile
        ],
        out_specs=pl.BlockSpec((bn, bh), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, feat), h.dtype),
        interpret=interpret,
    )(h, nbr_idx, weights)
