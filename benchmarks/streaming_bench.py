"""Streaming serving benchmark — throughput, latency tails, staleness curves,
and the multi-worker speed-layer sweep.

Drives synthetic checkout streams through the full engine
(ingest -> async-able batch refresh -> micro-batched speed layer) and reports:

* **throughput** (closed loop): events/s with micro-batching (batch >= 8)
  vs per-request scoring (max_batch=1) — the amortization win of coalescing
  concurrent traffic into one fixed-shape jit call;
* **latency** (open loop): p50/p95/p99 of queue-wait + service under
  Poisson arrivals, for several offered loads;
* **staleness vs accuracy**: ROC-AUC of the streamed scores as the batch
  layer's refresh cadence stretches — the Lambda trade-off quantified;
* **worker sweep** (``run_multiworker_bench``): p50/p95/p99, queue-depth and
  steal-rate counters vs worker count N under a virtual per-flush service
  cost — the N-server queueing win of sharding the micro-batch queue, plus
  the replay bit-parity check.  Lands in
  ``experiments/BENCH_multiworker.json``;
* **batched refresh puts**: per-embedding ``KVStore.put`` loop vs one
  ``put_batch`` call (what ``BatchLayer.refresh`` / ``RefreshDriver`` now
  use) — single lock/clock acquisition amortized over a whole refresh.

Every engine here is constructed through the one ``ServiceConfig`` artifact
(``repro.service``) — no hand-wired kwargs.

Run:  PYTHONPATH=src python benchmarks/streaming_bench.py [--smoke]
JSON lands in experiments/BENCH_streaming.json + BENCH_multiworker.json
(also wired into benchmarks/run.py; ``--smoke`` shrinks every dimension to
CI-smoke sizes — seconds, not minutes).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def _fresh_service(params, cfg, *, max_batch=16, max_wait_s=0.005,
                   refresh_every=1, num_workers=1, service_model_s=0.0,
                   steal_threshold=None, store_shards=4,
                   community_local=True, community_size=4096):
    """Construct a streaming FraudService from ONE ServiceConfig artifact —
    the only way benches build engines now."""
    from repro.service import FraudService, ModelSection, ServiceConfig

    sc = ServiceConfig(
        mode="streaming", model=ModelSection.from_lnn_config(cfg),
    ).replace(
        engine={"max_batch": max_batch, "max_wait_s": max_wait_s,
                "num_workers": num_workers,
                "service_model_s": service_model_s,
                "steal_threshold": steal_threshold},
        store={"num_shards": store_shards},
        refresh={"refresh_every": refresh_every,
                 "community_local": community_local,
                 "community_size": community_size},
    )
    return FraudService(sc, params=params).build()


def run_put_batch_bench(dim: int = 64, n: int = 20000, shards: int = 4) -> dict:
    """Refresh write path: per-embedding ``put`` loop vs one ``put_batch``
    (single lock + clock acquisition, one eviction sweep per shard)."""
    from repro.serve.kvstore import KVStore, pack_key

    vals = np.random.default_rng(0).standard_normal((n, dim)).astype(np.float32)
    keys = [pack_key(i, 0) for i in range(n)]
    loop_store = KVStore(dim, num_shards=shards)
    t0 = time.perf_counter()
    for k, v in zip(keys, vals):
        loop_store.put(k, v, version=1)
    loop_s = time.perf_counter() - t0
    batch_store = KVStore(dim, num_shards=shards)
    t0 = time.perf_counter()
    batch_store.put_batch(keys, vals, version=1)
    batch_s = time.perf_counter() - t0
    assert len(batch_store) == len(loop_store) == n
    return {"n": n, "dim": dim, "loop_put_s": loop_s, "put_batch_s": batch_s,
            "speedup": loop_s / batch_s}


def run_streaming_bench(
    num_users: int = 250,
    num_rings: int = 6,
    batch_sizes=(1, 8, 16),
    loads_per_s=(100.0, 400.0),
    refresh_intervals=(1, 4, 10),
    train_epochs: int = 12,
    seed: int = 0,
) -> dict:
    import jax

    from repro.core import LNNConfig, lnn_init
    from repro.data import SynthConfig, build_communities, generate_event_stream
    from repro.train.metrics import roc_auc

    scfg = SynthConfig(num_users=num_users, num_rings=num_rings,
                       feature_noise=0.8, seed=seed)
    events, g, split = generate_event_stream(scfg, rate_per_s=400.0)
    cfg = LNNConfig(num_gnn_layers=3, hidden_dim=64,
                    feat_dim=g.order_features.shape[1], pos_weight=3.0)
    if train_epochs:
        # a briefly-trained model makes the staleness-vs-accuracy curve
        # meaningful (random embeddings carry no freshness signal)
        from repro.train.loop import train_lnn

        comm = build_communities(g, community_size=256, max_deg=24)
        params = train_lnn(comm, split, cfg, epochs=train_epochs,
                           patience=train_epochs, seed=seed).params
    else:
        params = lnn_init(jax.random.PRNGKey(seed), cfg)
    out: dict = {"n_events": len(events), "config": {
        "num_users": num_users, "num_rings": num_rings, "hidden_dim": cfg.hidden_dim,
    }}

    # ---- throughput: closed loop (arrivals never throttle the engine) ------
    # one ingest+refresh pass populates the store; scoring is then re-driven
    # back-to-back per batch size so only the speed-layer path is timed.
    svc = _fresh_service(params, cfg, max_batch=max(batch_sizes), refresh_every=1)
    svc.replay(events)
    eng = svc.engine
    key_lists = [eng.ingester.builder.entity_keys(ev.entities, ev.snapshot)
                 for ev in events]
    feats = np.stack([ev.features for ev in events]).astype(np.float32)

    eng.warmup()          # compile every pow2 bucket once, off the clock
    thr = {}
    for bs in batch_sizes:
        # warm the exact full-chunk shape too: bucket padding floors at 2,
        # so engine warmup alone no longer covers a bare batch-1 dispatch
        eng._score_batch(np.zeros((bs, feats.shape[1]), np.float32),
                         [[] for _ in range(bs)])
        t0 = time.perf_counter()
        for i in range(0, len(events), bs):
            chunk_f, chunk_k = feats[i:i + bs], key_lists[i:i + bs]
            n = len(chunk_k)
            if n < bs:   # tail: pad to the warmed bucket like the batcher does
                from repro.stream.microbatch import bucket_size

                b = bucket_size(n, bs)
                chunk_f = np.concatenate(
                    [chunk_f, np.zeros((b - n, feats.shape[1]), np.float32)]
                )
                chunk_k = chunk_k + [[] for _ in range(b - n)]
            eng._score_batch(chunk_f, chunk_k)
        dt = time.perf_counter() - t0
        thr[f"batch_{bs}"] = {
            "events_per_s": len(events) / dt,
            "us_per_event": dt / len(events) * 1e6,
        }
    out["throughput"] = thr
    base = thr["batch_1"]["events_per_s"]
    best_bs = max(b for b in batch_sizes if b >= 8) if any(
        b >= 8 for b in batch_sizes) else max(batch_sizes)
    out["microbatch_speedup"] = thr[f"batch_{best_bs}"]["events_per_s"] / base

    # ---- latency under Poisson load (open loop, full engine) ---------------
    lat = {}
    for rate in loads_per_s:
        evs, _, _ = generate_event_stream(scfg, rate_per_s=rate)
        rep = _fresh_service(params, cfg, max_batch=16, max_wait_s=0.005,
                             refresh_every=1).replay(evs)
        s = rep.summary()
        lat[f"load_{int(rate)}eps"] = {
            **s["latency_ms"],
            "mean_ms": s["mean_latency_ms"],
            "mean_batch": s["mean_batch"],
            "size_flushes": s["size_flushes"],
            "deadline_flushes": s["deadline_flushes"],
        }
    out["latency"] = lat

    # ---- staleness vs accuracy ---------------------------------------------
    labels = np.asarray([ev.label for ev in events])
    curve = []
    for every in refresh_intervals:
        lazy = _fresh_service(params, cfg, max_batch=16, refresh_every=every)
        rep = lazy.replay(events)
        scores_by_order = rep.scores_by_order()
        scores = np.asarray([scores_by_order[ev.order_id] for ev in events])
        point = {
            "refresh_every": every,
            "refreshes": lazy.engine.refresher.stats["refreshes"],
            "staleness_mean": rep.staleness_summary()["mean"],
            "stale_frac": rep.staleness_summary()["stale_frac"],
            "kv_misses": lazy.store.stats["misses"],
        }
        if 0 < labels.sum() < labels.size:
            point["roc_auc"] = roc_auc(labels, scores)
        curve.append(point)
    out["staleness_curve"] = curve
    return out


def _cohort_stream(num_cohorts: int, cohort_users: int, cohort_snapshots: int,
                   rate_per_s: float, seed: int):
    """A growing-universe event stream: cohort k's users are active only in
    snapshot window [k*S, (k+1)*S) with fresh entity ids, so the accumulated
    graph grows linearly while per-window traffic stays bounded — the
    unbounded-replay regime where whole-graph refresh cost diverges and
    community-local cost should stay flat."""
    import dataclasses

    from repro.data import SynthConfig, generate_event_stream

    events = []
    ent_off = 0
    t_off = 0.0
    for k in range(num_cohorts):
        evs, g, _ = generate_event_stream(
            SynthConfig(num_users=cohort_users, num_rings=1,
                        num_snapshots=cohort_snapshots, feature_noise=0.8,
                        seed=seed + k),
            rate_per_s=rate_per_s,
        )
        for ev in evs:
            events.append(dataclasses.replace(
                ev,
                order_id=len(events),
                snapshot=ev.snapshot + k * cohort_snapshots,
                entities=tuple(e + ent_off for e in ev.entities),
                arrival=ev.arrival + t_off,
            ))
        ent_off += g.num_entities
        t_off = events[-1].arrival if events else 0.0
    return events


def run_refresh_bench(
    num_cohorts: int = 10,
    cohort_users: int = 40,
    cohort_snapshots: int = 4,
    rate_per_s: float = 500.0,
    refresh_every: int = 1,
    community_size: int = 4096,
    seed: int = 0,
) -> dict:
    """Refresh-cost-vs-stream-length curve: whole-graph vs community-local.

    Replays one growing-universe stream twice — ``community_local=False``
    (pad + stage-1 over the entire accumulated DDS graph every refresh)
    and ``community_local=True`` (materialize + pad only the connected
    components containing dirty pairs, bin-packed to ``community_size``
    nodes).  Per-refresh cost is measured in **padded stage-1 nodes**
    (deterministic, host-independent) plus wall seconds; the record keeps
    the whole per-refresh curve.  ``growth`` is mean(last half of the
    curve) / mean(first half): ~linear cost doubles+ over the stream,
    community-local stays ~flat — ``sublinear`` gates exactly that, and
    ``parity.bit_identical`` gates that both paths replayed to identical
    scores (the exactness invariant, also unit-tested).
    """
    import jax

    from repro.core import LNNConfig, lnn_init

    events = _cohort_stream(num_cohorts, cohort_users, cohort_snapshots,
                            rate_per_s, seed)
    feat_dim = events[0].features.shape[0]
    cfg = LNNConfig(num_gnn_layers=3, hidden_dim=64, feat_dim=feat_dim,
                    pos_weight=3.0)
    params = lnn_init(jax.random.PRNGKey(seed), cfg)

    out: dict = {
        "n_events": len(events),
        "config": {
            "num_cohorts": num_cohorts, "cohort_users": cohort_users,
            "cohort_snapshots": cohort_snapshots,
            "refresh_every": refresh_every, "community_size": community_size,
            "hidden_dim": cfg.hidden_dim,
        },
        "modes": {},
    }
    scores: dict = {}
    for name, community_local in (("full", False), ("community", True)):
        svc = _fresh_service(params, cfg, max_batch=16,
                             refresh_every=refresh_every,
                             community_local=community_local,
                             community_size=community_size)
        t0 = time.perf_counter()
        rep = svc.replay(events)
        wall = time.perf_counter() - t0
        scores[name] = rep.scores_by_order()
        st = svc.engine.refresher.stats
        hist = list(st["budget_history"])
        half = max(1, len(hist) // 2)
        growth = (float(np.mean(hist[half:])) / max(float(np.mean(hist[:half])), 1e-9)
                  if len(hist) > 1 else 1.0)
        out["modes"][name] = {
            "refreshes": st["refreshes"],
            "entities_written": st["entities_written"],
            "stage1_seconds": st["seconds"],
            "replay_wall_s": wall,
            "nodes_padded_total": st["nodes_padded"],
            "stage1_launches": st["stage1_launches"],
            "final_refresh_nodes": hist[-1] if hist else 0,
            "growth": growth,
            "curve": [{"refresh": i, "padded_nodes": b}
                      for i, b in enumerate(hist)],
        }
    full, comm = out["modes"]["full"], out["modes"]["community"]
    out["nodes_speedup_total"] = full["nodes_padded_total"] / max(
        comm["nodes_padded_total"], 1)
    out["nodes_speedup_final"] = full["final_refresh_nodes"] / max(
        comm["final_refresh_nodes"], 1)
    # sublinear gate: whole-graph per-refresh cost keeps growing with the
    # stream; community-local must grow strictly slower AND end far cheaper
    out["sublinear"] = bool(comm["growth"] < 0.5 * full["growth"]
                            and out["nodes_speedup_final"] >= 2.0)
    sf, sc_ = scores["full"], scores["community"]
    out["parity"] = {
        "bit_identical": bool(set(sf) == set(sc_)
                              and all(sc_[o] == sf[o] for o in sf)),
        "checked_events": len(sf),
    }
    return out


def _print_refresh(r: dict) -> None:
    print("\n# Batch-layer refresh scope "
          f"({r['config']['num_cohorts']} cohorts, {r['n_events']} events)")
    for name, m in r["modes"].items():
        print(f"  {name:9s}: {m['refreshes']} refreshes, "
              f"{m['nodes_padded_total']} padded nodes total "
              f"(final {m['final_refresh_nodes']}), growth {m['growth']:.2f}x, "
              f"stage1 {m['stage1_seconds']*1e3:.0f}ms")
    print(f"  community-local padded-node win: "
          f"{r['nodes_speedup_total']:.1f}x total, "
          f"{r['nodes_speedup_final']:.1f}x on the final refresh; "
          f"sublinear={r['sublinear']} "
          f"parity={r['parity']['bit_identical']}")


def run_multiworker_bench(
    num_users: int = 200,
    num_rings: int = 5,
    worker_counts=(1, 2, 4, 8),
    rate_per_s: float = 600.0,
    max_batch: int = 16,
    max_wait_s: float = 0.005,
    service_model_s: float = 0.004,
    steal_threshold: int = 24,
    parity_events: int = 150,
    seed: int = 0,
) -> dict:
    """Worker-count sweep over the sharded speed layer.

    The engine is a deterministic N-server queueing simulation: each flush
    occupies its worker for ``service_model_s`` *virtual* seconds, so at a
    fixed offered load a single worker saturates (queue waits dominate the
    tail) while N key-affine workers drain in parallel — the latency
    columns quantify exactly the serving-tier scaling the sharded queue
    buys, independent of host speed.  Wall-clock replay throughput is also
    reported, with the honest caveat that all N workers share this
    process's one CPU (jit dispatch concurrency is simulated, not real).
    Queue-depth and steal-rate counters come from the pool's own stats.
    """
    import jax

    from repro.core import LNNConfig, lnn_init
    from repro.data import SynthConfig, generate_event_stream

    scfg = SynthConfig(num_users=num_users, num_rings=num_rings,
                       feature_noise=0.8, seed=seed)
    events, g, _ = generate_event_stream(scfg, rate_per_s=rate_per_s)
    cfg = LNNConfig(num_gnn_layers=3, hidden_dim=64,
                    feat_dim=g.order_features.shape[1], pos_weight=3.0)
    params = lnn_init(jax.random.PRNGKey(seed), cfg)

    out: dict = {
        "n_events": len(events),
        "config": {
            "num_users": num_users, "rate_per_s": rate_per_s,
            "max_batch": max_batch, "max_wait_s": max_wait_s,
            "service_model_s": service_model_s,
            "steal_threshold": steal_threshold,
            "hidden_dim": cfg.hidden_dim,
        },
        "sweep": [],
    }

    for n in worker_counts:
        svc = _fresh_service(params, cfg, max_batch=max_batch,
                             max_wait_s=max_wait_s, num_workers=n,
                             service_model_s=service_model_s,
                             steal_threshold=steal_threshold)
        t0 = time.perf_counter()
        rep = svc.replay(events)
        wall = time.perf_counter() - t0
        s = rep.summary()
        workers = s["workers"]
        out["sweep"].append({
            "num_workers": n,
            "events_per_s_wall": len(events) / wall,
            "latency_ms": s["latency_ms"],
            "mean_latency_ms": s["mean_latency_ms"],
            "mean_batch": s["mean_batch"],
            "flushes": s["flushes"],
            "steals": s["steals"],
            "stolen_requests": s["stolen_requests"],
            "steal_rate": s["stolen_requests"] / max(1, len(events)),
            "max_queue_depth": max(w["max_queue_depth"] for w in workers),
            "mean_queue_depth": float(np.mean(
                [w["mean_queue_depth"] for w in workers])),
            "per_worker_requests": [w["requests"] for w in workers],
            "workers": workers,
        })

    # replay bit-parity: the acceptance invariant, checked on a prefix
    evs = events[:parity_events]
    ref = _fresh_service(params, cfg, max_batch=max_batch)
    s_ref = ref.replay(evs).scores_by_order()
    bit_identical = True
    for n in worker_counts:
        svc = _fresh_service(params, cfg, max_batch=max_batch, num_workers=n,
                             service_model_s=service_model_s,
                             steal_threshold=steal_threshold)
        s_n = svc.replay(evs).scores_by_order()
        bit_identical &= (set(s_n) == set(s_ref)
                          and all(s_n[o] == s_ref[o] for o in s_ref))
    out["parity"] = {"bit_identical": bool(bit_identical),
                     "checked_events": len(evs),
                     "worker_counts": list(worker_counts)}
    return out


def _print_multiworker(r: dict) -> None:
    print("\n# Multi-worker sharded speed layer "
          f"(virtual service {r['config']['service_model_s']*1e3:.1f} ms/flush)")
    for p in r["sweep"]:
        pct = p["latency_ms"]
        print(f"  N={p['num_workers']}: p50={pct['p50']:.2f}ms "
              f"p95={pct['p95']:.2f}ms p99={pct['p99']:.2f}ms "
              f"max_depth={p['max_queue_depth']} "
              f"steal_rate={p['steal_rate']:.3f} "
              f"wall={p['events_per_s_wall']:.0f} eps")
    par = r["parity"]
    print(f"  replay parity: bit_identical={par['bit_identical']} "
          f"over N={par['worker_counts']} ({par['checked_events']} events)")


def run_hetero_bench(
    attack_cfg=None,
    review_budgets=(0.02, 0.05, 0.10),
    train_frac: float = 0.6,
    mlp_epochs: int = 60,
    gbdt_trees: int = 40,
    parity_events: int = 200,
    seed: int = 0,
) -> dict:
    """Heterogeneous named-attack workload: per-attack recall curves and the
    hybrid GNN->GBDT head vs the tabular MLP baseline.

    Replays the typed attack stream (``repro.data.attacks``) through a
    heterogeneous streaming service (type-tagged entity ids, per-type
    towers), then scores the *time-split* test tail three ways against the
    store's snapshot-versioned embeddings (each order reads keys strictly
    before its own snapshot — no future leak):

    * ``mlp_raw``   — the tabular MLP baseline on raw checkout features;
    * ``gbdt_raw``  — the booster on the same raw features;
    * ``hybrid``    — GBDT over the frozen GNN's pre-MLP stage-2 embedding
      (``models.hybrid``): the graph linkage signal, tree-readable.

    Recall@budget: fraction of each attack's fraud orders inside the top
    ``budget`` fraction of test orders by score — the review-queue metric a
    fraud-ops team actually staffs against.  Fraud rings are pure linkage
    (shared devices/tokens, weak raw features), so the hybrid must beat the
    raw-feature MLP on ring recall — ``gates.hybrid_beats_mlp_on_rings``.
    ``gates.typed_replay_parity`` re-replays the stream and demands
    bit-identical scores (determinism extends to typed graphs).
    """
    import jax

    from repro.baselines import GBDTConfig, MLPConfig, mlp_forward, train_gbdt, train_mlp
    from repro.core import ENTITY_TYPE_NAMES, LNNConfig, lnn_init, lnn_stage2_embed
    from repro.data.attacks import ATTACK_NAMES, AttackConfig, generate_attack_stream
    from repro.models.hybrid import train_hybrid
    from repro.train.metrics import roc_auc

    acfg = attack_cfg or AttackConfig(seed=seed)
    events, patterns = generate_attack_stream(acfg)
    labels = np.asarray([ev.label for ev in events])
    feats = np.stack([ev.features for ev in events]).astype(np.float32)
    cfg = LNNConfig(num_gnn_layers=2, hidden_dim=32,
                    feat_dim=feats.shape[1], pos_weight=3.0,
                    entity_types=ENTITY_TYPE_NAMES)
    params = lnn_init(jax.random.PRNGKey(seed), cfg)

    svc = _fresh_service(params, cfg, max_batch=16)
    svc.replay(events)
    eng = svc.engine

    # snapshot-versioned embeddings at each order's own event time
    key_lists = [eng.ingester.builder.entity_keys(ev.entities, ev.snapshot)
                 for ev in events]
    k_max = svc.config.engine.k_max
    emb, mask, _ = svc.store.lookup_batch_versioned(key_lists, k_max)
    slot_type = eng.pool.workers[0].scorer._slot_types(key_lists)
    x = np.asarray(lnn_stage2_embed(params, cfg, emb, mask, feats,
                                    slot_type=slot_type), np.float32)

    # time split: train on the first snapshots, evaluate on the tail
    snaps = np.asarray([ev.snapshot for ev in events])
    cut = int(round(acfg.num_snapshots * train_frac))
    train, test = snaps < cut, snaps >= cut
    y_tr, y_te = labels[train], labels[test]
    pat_te = patterns[test]

    # small validation tail of the train window for early stopping
    val = train & (snaps >= max(cut - 2, 1))
    fit = train & ~val
    if not val.any() or not fit.any():
        fit, val = train, train
    mlp_params = train_mlp(feats[fit], labels[fit], feats[val], labels[val],
                           MLPConfig(epochs=mlp_epochs, pos_weight=3.0,
                                     seed=seed))
    gcfg = GBDTConfig(num_trees=gbdt_trees)
    gbdt_raw = train_gbdt(feats[train].astype(np.float64), y_tr, cfg=gcfg)
    hybrid = train_hybrid(params, cfg, x[train], y_tr, gbdt_cfg=gcfg)

    scores = {
        "mlp_raw": np.asarray(
            1.0 / (1.0 + np.exp(-np.asarray(
                mlp_forward(mlp_params, feats[test]), np.float64)))),
        "gbdt_raw": gbdt_raw.predict_proba(feats[test].astype(np.float64)),
        "hybrid": hybrid.gbdt.predict_proba(x[test]),
    }

    def recall_curves(s: np.ndarray) -> dict:
        order = np.argsort(-s, kind="stable")
        out = {}
        for b in review_budgets:
            top = np.zeros(s.size, bool)
            top[order[: max(1, int(round(b * s.size)))]] = True
            out[f"budget_{b:g}"] = {
                a: (float((top & (pat_te == a)).sum() / max((pat_te == a).sum(), 1)))
                for a in ATTACK_NAMES
            }
        return out

    recall = {name: recall_curves(s) for name, s in scores.items()}
    aucs = {name: (roc_auc(y_te, s) if 0 < y_te.sum() < y_te.size else None)
            for name, s in scores.items()}

    # sum ring recall across budgets — one aggregate comparison is far more
    # stable across seeds/sizes than any single point on the curve
    hybrid_rings = sum(recall["hybrid"][b]["ring"] for b in recall["hybrid"])
    mlp_rings = sum(recall["mlp_raw"][b]["ring"] for b in recall["mlp_raw"])

    # determinism on typed graphs: fresh service, same stream, same bits
    evs = events[:parity_events]
    s_a = _fresh_service(params, cfg, max_batch=16).replay(evs).scores_by_order()
    s_b = _fresh_service(params, cfg, max_batch=16).replay(evs).scores_by_order()
    parity = bool(set(s_a) == set(s_b) and all(s_b[o] == s_a[o] for o in s_a))

    per_attack = {a: int((patterns == a).sum()) for a in ATTACK_NAMES}
    per_attack["legit"] = int((patterns == "legit").sum())
    return {
        "n_events": len(events),
        "config": {
            "num_buyers": acfg.num_buyers, "num_merchants": acfg.num_merchants,
            "num_rings": acfg.num_rings, "num_bursts": acfg.num_bursts,
            "num_bin_runs": acfg.num_bin_runs,
            "num_snapshots": acfg.num_snapshots,
            "entity_types": list(ENTITY_TYPE_NAMES),
            "hidden_dim": cfg.hidden_dim, "gbdt_trees": gbdt_trees,
            "train_frac": train_frac,
        },
        "attacks": per_attack,
        "test_events": int(test.sum()),
        "test_fraud": int(y_te.sum()),
        "recall": recall,
        "auc": aucs,
        "gates": {
            "hybrid_beats_mlp_on_rings": bool(hybrid_rings > mlp_rings),
            "typed_replay_parity": parity,
        },
    }


def _print_hetero(r: dict) -> None:
    print("\n# Heterogeneous named-attack workload "
          f"({r['n_events']} events, {r['test_fraud']} test frauds)")
    counts = ", ".join(f"{a}={n}" for a, n in r["attacks"].items())
    print(f"  attacks: {counts}")
    budgets = sorted(next(iter(r["recall"].values())).keys())
    for model, curves in r["recall"].items():
        auc = r["auc"].get(model)
        auc_s = f" auc={auc:.3f}" if auc is not None else ""
        parts = []
        for b in budgets:
            ring = curves[b]["ring"]
            parts.append(f"{b.split('_')[1]}:ring={ring:.2f}")
        print(f"  {model:9s}{auc_s}  recall@[{' '.join(parts)}]")
    g = r["gates"]
    print(f"  gates: hybrid_beats_mlp_on_rings={g['hybrid_beats_mlp_on_rings']} "
          f"typed_replay_parity={g['typed_replay_parity']}")


def main(smoke: bool = False) -> dict:
    if smoke:
        r = run_streaming_bench(num_users=60, num_rings=2, batch_sizes=(1, 8),
                                loads_per_s=(200.0,), refresh_intervals=(1, 4),
                                train_epochs=0)
        mw = run_multiworker_bench(num_users=60, num_rings=2,
                                   worker_counts=(1, 2), parity_events=60)
        rf = run_refresh_bench(num_cohorts=5, cohort_users=25,
                               cohort_snapshots=3)
        from repro.data.attacks import AttackConfig

        ht = run_hetero_bench(
            AttackConfig(num_buyers=80, num_merchants=15, num_rings=3,
                         ring_size=6, num_bursts=2, burst_orders=15,
                         num_bin_runs=2, bin_cards=12, num_snapshots=12),
            mlp_epochs=30, gbdt_trees=30, parity_events=80)
        r["refresh_put_batch"] = run_put_batch_bench(n=5000)
    else:
        r = run_streaming_bench()
        mw = run_multiworker_bench()
        rf = run_refresh_bench()
        ht = run_hetero_bench()
        r["refresh_put_batch"] = run_put_batch_bench()
    print("\n# Streaming serving engine")
    for bs, t in r["throughput"].items():
        print(f"  throughput/{bs}: {t['events_per_s']:.0f} events/s "
              f"({t['us_per_event']:.0f} us/event)")
    print(f"  micro-batch speedup (batch>=8 vs per-request): "
          f"{r['microbatch_speedup']:.1f}x")
    for load, pct in r["latency"].items():
        print(f"  latency/{load}: p50={pct['p50']:.2f}ms p95={pct['p95']:.2f}ms "
              f"p99={pct['p99']:.2f}ms (mean batch {pct['mean_batch']:.1f})")
    for p in r["staleness_curve"]:
        auc = f" auc={p['roc_auc']:.4f}" if "roc_auc" in p else ""
        print(f"  staleness/refresh_every={p['refresh_every']}: "
              f"mean={p['staleness_mean']:.2f} snapshots, "
              f"stale_frac={p['stale_frac']:.2f}{auc}")
    pb = r["refresh_put_batch"]
    print(f"  refresh writes: {pb['n']} embeddings, put-loop "
          f"{pb['loop_put_s']*1e3:.1f}ms vs put_batch "
          f"{pb['put_batch_s']*1e3:.1f}ms ({pb['speedup']:.1f}x)")
    _print_multiworker(mw)
    _print_refresh(rf)
    _print_hetero(ht)
    # smoke records land in experiments/smoke/ so a local `--smoke` run can
    # never clobber the curated full-run records
    outdir = os.path.join("experiments", "smoke") if smoke else "experiments"
    os.makedirs(outdir, exist_ok=True)
    with open(os.path.join(outdir, "BENCH_streaming.json"), "w") as f:
        json.dump(r, f, indent=1)
    with open(os.path.join(outdir, "BENCH_multiworker.json"), "w") as f:
        json.dump(mw, f, indent=1)
    with open(os.path.join(outdir, "BENCH_refresh.json"), "w") as f:
        json.dump(rf, f, indent=1)
    with open(os.path.join(outdir, "BENCH_hetero.json"), "w") as f:
        json.dump(ht, f, indent=1)
    r["multiworker"] = mw
    r["refresh_scope"] = rf
    r["hetero"] = ht
    return r


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI smoke (seconds, not minutes)")
    main(smoke=ap.parse_args().smoke)
