"""Ablations extending the paper's evaluation.

1. **Future-leak ablation** (the paper's core motivation): replace the DDS
   graph with a static *undirected* order<->entity graph — entities aggregate
   ALL linked orders including future ones, exactly the condition DDS is
   designed to prevent.  Since our `past_chargebacks` feature is
   label-derived (with reporting delay), future information flowing through
   entities is genuine leakage: expect inflated fit on seen time ranges and
   a larger generalization gap vs DDS.
2. **Partition size** — the paper: "It would be interesting to further
   explore how could the partition size impact our model performance."  We
   sweep community_size and answer.
3. **Entity history** — 'all' past snapshots vs 'consecutive' chaining.

Run: PYTHONPATH=src python -m benchmarks.ablations
"""
from __future__ import annotations

import json

import numpy as np


def _make_leaky_batches(static, community_size=256, max_deg=24, seed=0):
    """Static undirected graph per community: order<->entity both directions,
    no snapshots, no shadows.  Entities see the future."""
    from repro.core.dds import DDSGraph
    from repro.core.graph import COOGraph, EdgeType, NodeType, pad_graph
    from repro.core.partition import partition_transactions
    from repro.data.pipeline import CommunityBatch
    from repro.utils.padding import pad_to_multiple

    comm = partition_transactions(static.num_orders, static.num_entities,
                                  static.edges, community_size=community_size,
                                  seed=seed)
    order_comm, entity_comm = comm[: static.num_orders], comm[static.num_orders:]
    raw = []
    for c in np.unique(comm):
        lo = np.nonzero(order_comm == c)[0]
        le = np.nonzero(entity_comm == c)[0]
        if lo.size < 4:
            continue
        keep = (order_comm[static.edges[:, 0]] == c) & (entity_comm[static.edges[:, 1]] == c)
        kept = static.edges[keep]
        if kept.size == 0:
            continue
        o_lut = np.full(static.num_orders, -1, np.int64)
        o_lut[lo] = np.arange(lo.size)
        e_lut = np.full(static.num_entities, -1, np.int64)
        e_lut[le] = np.arange(le.size)
        o_local = o_lut[kept[:, 0]]
        e_local = e_lut[kept[:, 1]] + lo.size          # entities after orders
        n = lo.size + le.size
        # undirected: order->entity (SHADOW_TO_ENTITY role) and
        # entity->order tagged as the final-hop type so the LNN head applies
        src = np.concatenate([o_local, e_local])
        dst = np.concatenate([e_local, o_local])
        et = np.concatenate([
            np.full(o_local.size, EdgeType.SHADOW_TO_ENTITY, np.int32),
            np.full(o_local.size, EdgeType.ENTITY_TO_ORDER, np.int32),
        ])
        feats = np.zeros((n, static.order_features.shape[1]), np.float32)
        feats[: lo.size] = static.order_features[lo]
        ntype = np.full(n, NodeType.ENTITY, np.int32)
        ntype[: lo.size] = NodeType.ORDER
        label = np.zeros(n, np.float32)
        label[: lo.size] = static.labels[lo]
        lmask = np.zeros(n, np.float32)
        lmask[: lo.size] = 1.0
        coo = COOGraph(num_nodes=n, src=src, dst=dst, etype=et, features=feats,
                       node_type=ntype, snapshot=np.zeros(n, np.int32),
                       label=label, label_mask=lmask)
        raw.append((coo, lo))
    budget = pad_to_multiple(max(c.num_nodes for c, _ in raw), 8)
    out = []
    for coo, lo in raw:
        pg = pad_graph(coo, num_nodes=budget, max_deg=max_deg)
        dds = DDSGraph(coo=coo, num_orders=lo.size, entity_snap_ids={}, last_hop={})
        out.append(CommunityBatch(graph=pg, global_order_ids=lo, dds=dds))
    return out


def run_ablations(seed: int = 0, epochs: int = 25):
    import jax

    from repro.core import LNNConfig
    from repro.data import (SynthConfig, build_communities,
                            generate_transactions, make_split_masks)
    from repro.data.pipeline import standardize_features
    from repro.train.loop import collect_scores, train_lnn
    from repro.train.metrics import average_precision, roc_auc
    from repro.core.lnn import lnn_forward

    g, _ = generate_transactions(SynthConfig(num_users=400, num_rings=6,
                                             feature_noise=0.8, seed=seed))
    split = make_split_masks(g.order_snapshot)
    feats, _ = standardize_features(g.order_features, split == 0)
    g.order_features = feats
    results = {}

    def fit_eval(batches, name):
        cfg = LNNConfig(gnn_type="gcn", num_gnn_layers=3, hidden_dim=64,
                        feat_dim=feats.shape[1], pos_weight=3.0)
        res = train_lnn(batches, split, cfg, epochs=epochs, patience=6, seed=seed)
        fwd = jax.jit(lambda p, gg: lnn_forward(p, cfg, gg))
        out = {}
        for which, nm in ((0, "train"), (1, "val"), (2, "test")):
            y, s = collect_scores(res.params, cfg, batches, split, which, fwd)
            out[nm] = {"auc": roc_auc(y, s), "ap": average_precision(y, s)}
        out["gap_auc"] = out["train"]["auc"] - out["test"]["auc"]
        results[name] = out
        print(f"  {name:28s} train AUC {out['train']['auc']:.4f}  "
              f"test AUC {out['test']['auc']:.4f}  gap {out['gap_auc']:+.4f}  "
              f"test AP {out['test']['ap']:.4f}")
        return out

    print("== 1. future-leak ablation (DDS vs static undirected) ==")
    fit_eval(build_communities(g, community_size=256, max_deg=24, seed=seed),
             "DDS (no future info)")
    fit_eval(_make_leaky_batches(g, community_size=256, seed=seed),
             "static undirected (leaky)")

    print("== 2. partition size (paper's open question) ==")
    for cs in (64, 256, 1024):
        fit_eval(build_communities(g, community_size=cs, max_deg=24, seed=seed),
                 f"community_size={cs}")

    print("== 3. entity history ==")
    for hist in ("all", "consecutive"):
        fit_eval(build_communities(g, community_size=256, max_deg=24,
                                   entity_history=hist, seed=seed),
                 f"entity_history={hist}")
    return results


if __name__ == "__main__":
    import os
    os.makedirs("experiments", exist_ok=True)
    res = run_ablations()
    json.dump(res, open("experiments/ablations.json", "w"), indent=1, default=float)
