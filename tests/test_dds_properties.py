"""Property-based tests (hypothesis) for the DDS graph — the paper's central
correctness claim: no information from the future of a checkout can reach it.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from repro.core.dds import StaticGraph, build_dds, check_no_future_leak
from repro.core.graph import EdgeType, NodeType, pad_graph


def random_static_graph(rng, num_orders, num_entities, num_snapshots, edge_prob=0.15):
    edges = []
    for o in range(num_orders):
        linked = rng.uniform(size=num_entities) < edge_prob
        for e in np.nonzero(linked)[0]:
            edges.append((o, e))
        if not linked.any():
            edges.append((o, rng.integers(num_entities)))
    return StaticGraph(
        num_orders=num_orders,
        num_entities=num_entities,
        edges=np.asarray(edges, np.int64),
        order_snapshot=rng.integers(0, num_snapshots, num_orders),
        order_features=rng.normal(size=(num_orders, 5)).astype(np.float32),
        labels=rng.integers(0, 2, num_orders).astype(np.float32),
    )


@settings(max_examples=30, deadline=None)
@given(
    num_orders=st.integers(3, 40),
    num_entities=st.integers(2, 15),
    num_snapshots=st.integers(2, 8),
    seed=st.integers(0, 1000),
    history=st.sampled_from(["all", "consecutive"]),
)
def test_no_future_leak_invariants(num_orders, num_entities, num_snapshots, seed, history):
    rng = np.random.default_rng(seed)
    g = random_static_graph(rng, num_orders, num_entities, num_snapshots)
    dds = build_dds(g, entity_history=history)
    check_no_future_leak(dds)   # asserts all four invariants


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000))
def test_final_hop_is_latest_strictly_past(seed):
    """Every ENTITY_TO_ORDER edge comes from the entity's most recent active
    snapshot strictly before the order (paper step 6: 0 <= t-e < t)."""
    rng = np.random.default_rng(seed)
    g = random_static_graph(rng, 30, 8, 6)
    dds = build_dds(g)
    coo = dds.coo
    # entity active snapshots
    active = {}
    for (ent, t) in dds.entity_snap_ids:
        active.setdefault(ent, []).append(t)
    node_of = {v: k for k, v in dds.entity_snap_ids.items()}
    fin = coo.etype == EdgeType.ENTITY_TO_ORDER
    for s, d in zip(coo.src[fin], coo.dst[fin]):
        ent, t_e = node_of[int(s)]
        t_order = int(coo.snapshot[d])
        past = [t for t in active[ent] if t < t_order]
        assert past, "edge from entity with no past activity"
        assert t_e == max(past)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), max_deg=st.integers(2, 12))
def test_padding_preserves_edges_up_to_cap(seed, max_deg):
    rng = np.random.default_rng(seed)
    g = random_static_graph(rng, 25, 6, 5)
    dds = build_dds(g)
    pg = pad_graph(dds.coo, max_deg=max_deg)
    # each real in-edge either appears in the padded rows or was degree-capped
    deg = dds.coo.in_degrees()
    kept = (pg.nbr_mask.sum(-1)).astype(int)
    for v in range(dds.coo.num_nodes):
        assert kept[v] == min(int(deg[v]), max_deg)
    # padded slots point at row 0 with zero mask and contribute nothing
    assert pg.nbr_idx[pg.nbr_mask == 0].max(initial=0) == 0


def test_shadow_orders_carry_no_labels(small_communities):
    for b in small_communities:
        g = b.graph
        lab = np.asarray(g.label_mask)
        types = np.asarray(g.node_type)
        assert (lab[types == NodeType.SHADOW] == 0).all()
        assert (lab[types == NodeType.ENTITY] == 0).all()
        assert (lab[types == NodeType.PAD] == 0).all()


def test_community_dds_invariants(small_communities):
    for b in small_communities:
        check_no_future_leak(b.dds)
