"""Crash-point fault injection — the hooks the recovery harness kills at.

A *crash point* is a named boundary on a durability-relevant code path
(WAL append, DDS ingest, micro-batch flush, batch-layer refresh, KV put,
checkpoint write).  In production every ``fire()`` is a no-op costing one
attribute read; the fault-injection harness (``tests/faultinject.py``)
arms exactly one point and the k-th crossing raises
:class:`SimulatedCrash` — modeling a process death at that instruction
boundary.  The recovery sweep then proves that restoring from the last
checkpoint + replaying the write-ahead log reproduces the uninterrupted
run bit-for-bit, whichever boundary the "process" died at.

This module is a dependency-free leaf on purpose: ``serve.kvstore`` and
``stream.*`` both import it, and neither may import the other (the
checkpoint layer in ``repro.stream.checkpoint`` already imports
``serve.kvstore``).

Only names in :data:`CRASH_POINTS` may fire or be armed — a typo'd name
is an error at arm/fire time, so the sweep in ``tests/test_faultinject.py``
(parametrized over ``CRASH_POINTS``) can never silently skip a boundary.
"""
from __future__ import annotations

#: every registered boundary, in rough hot-path order.  ``.before``/
#: ``.after`` pairs model dying just before vs just after the operation's
#: side effects; ``checkpoint.mid`` fires after the state payload is on
#: disk but before the manifest rename that commits it (a torn checkpoint
#: must be invisible to recovery).
CRASH_POINTS = (
    "wal.append.before",
    "wal.append.after",
    "ingest.before",
    "ingest.after",
    "flush.before_score",
    "flush.after_score",
    "refresh.before_stage1",
    "refresh.before_puts",
    "refresh.after",
    "kv.put_batch.before",
    "kv.put_batch.after",
    "checkpoint.before",
    "checkpoint.mid",
    "checkpoint.after",
    # process-backend only: fires in the parent just before a SCORE frame is
    # posted to a shard process; the harness converts it into a SIGKILL of
    # that child (tests/test_procpool.py) — the inline pool never crosses it
    "worker_kill",
)

_KNOWN = frozenset(CRASH_POINTS)


class SimulatedCrash(BaseException):
    """The injected process death.

    Derives from ``BaseException`` so no hot-path ``except Exception``
    recovery handler can swallow it — a real SIGKILL is not catchable
    either.  Carries the point name and the firing count at which it
    tripped.
    """

    def __init__(self, point: str, hit: int):
        super().__init__(f"simulated crash at {point!r} (hit #{hit})")
        self.point = point
        self.hit = hit


# module-level armed state: (name, trip-on-hit) or None.  One point at a
# time — the harness models one process death per run.
_ARMED: tuple | None = None
_fired = 0


def arm(name: str, hit: int = 1) -> None:
    """Arm ``name``: the ``hit``-th ``fire(name)`` raises SimulatedCrash."""
    global _ARMED, _fired
    if name not in _KNOWN:
        raise ValueError(f"unknown crash point {name!r}; registered: {CRASH_POINTS}")
    if hit < 1:
        raise ValueError("hit must be >= 1")
    _ARMED = (name, int(hit))
    _fired = 0


def disarm() -> None:
    """Return to the production no-op state (idempotent)."""
    global _ARMED, _fired
    _ARMED = None
    _fired = 0


def armed() -> str | None:
    """The armed point name, or None."""
    return _ARMED[0] if _ARMED is not None else None


def fire(name: str) -> None:
    """Cross the boundary ``name``.  No-op unless that point is armed."""
    global _fired
    if _ARMED is None or _ARMED[0] != name:
        return
    _fired += 1
    if _fired >= _ARMED[1]:
        disarm()  # one death per arm(); recovery code must not re-trip
        raise SimulatedCrash(name, _fired)


__all__ = ["CRASH_POINTS", "SimulatedCrash", "arm", "armed", "disarm", "fire"]
